"""GDPR compliance arm: certification sweep, deletion SLOs, rank drift.

Three phases, one ``arm="compliance"`` entry in ``BENCH_updates.json``
(DESIGN.md §11, ISSUE 9):

* **certification sweep** — drive randomized deletion-burst streams
  (basket-level and item-level deletions interleaved with adds, plus a
  full user-level ``forget_user``) through single and sharded engines
  and run ``repro.compliance.certify`` on each; every stream must
  certify, and a deliberately tampered stream (one deletion skipped)
  must be DETECTED — the sweep validates the detector in both
  directions.
* **deletion latency under mixed serve traffic** — time individually
  submitted+drained deletions and full ``forget_user`` calls while
  ``recommend`` batches interleave, and report p50/p95/p99 plus
  normalized SLO fractions (``*_over_slo`` = measured p99 / objective;
  the trend gate enforces ``<= 1.0``).
* **ranking drift** — fit a synthetic dataset through the engine's add
  path, evaluate Recall@{10,20} / NDCG@{10,20} via the
  ``table2_predictive`` evaluation path, apply a deletion burst
  (random basket deletions for a user fraction + user-level forgets),
  and report the signed metric drift on the retained users.

Summary keys follow the EN03 convention (repro.analysis.bench_schema):
``*_ms`` percentiles, ``*qps*``, ``*drift*``, ``*certified*``,
``*swept*`` and ``*overlap*`` are parity facts; ``*_over_slo`` is
gated-slo (hard ``<= 1.0``).

    PYTHONPATH=src python benchmarks/bench_compliance.py          # full
    PYTHONPATH=src python benchmarks/bench_compliance.py --smoke  # CI

``--smoke`` shrinks the sweep (streams/events/deletions) so the CI
bench job exercises the full harness in seconds on CPU; its numbers
validate plumbing, not perf.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import tempfile
import time

import jax
import numpy as np

try:
    from repro.compliance import certify
except ImportError:  # run as a plain script without PYTHONPATH=src
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "src"))
    from repro.compliance import certify

from repro.core.types import (KIND_ADD_BASKET, KIND_DEL_BASKET,
                              KIND_DEL_ITEM, TifuParams)
from repro.data import synthetic
from repro.kernels import ops
from repro.parallel.sharding import UserShardSpec
from repro.streaming import (Event, ShardedStreamingEngine, StateStore,
                             StoreConfig, StreamingEngine)

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import table2_predictive                                   # noqa: E402
from bench_update_batch import BACKEND_IMPL, merge_runs    # noqa: E402


@dataclasses.dataclass(frozen=True)
class ComplianceConfig:
    """Knobs of the compliance arm (SMOKE shrinks every dimension)."""

    # phase 1: certification sweep
    n_streams: int = 100
    n_users: int = 8
    n_events: int = 120
    n_items: int = 41
    max_baskets: int = 24
    max_basket_size: int = 6
    checkpoint_every: int = 10     # round-trip check on every Nth stream
    # phase 2: deletion latency under serve traffic
    latency_users: int = 64
    latency_prefill: int = 6       # baskets per user before the burst
    latency_deletions: int = 300
    latency_forgets: int = 8
    serve_every: int = 5           # a recommend batch every N deletions
    serve_batch: int = 8
    deletion_slo_ms: float = 250.0
    forget_slo_ms: float = 2000.0
    # phase 3: ranking drift through the table2_predictive path
    drift_dataset: str = "tafeng"
    drift_scale: float = 0.05
    drift_seed: int = 0
    drift_user_frac: float = 0.25  # fraction of users hit by the burst
    drift_basket_frac: float = 0.3
    drift_forgets: int = 2


SMOKE = ComplianceConfig(
    n_streams=3, n_events=60, checkpoint_every=2, latency_users=16,
    latency_prefill=4, latency_deletions=30, latency_forgets=2,
    drift_scale=0.01, drift_forgets=1)


def _params(cfg: ComplianceConfig) -> TifuParams:
    """The sweep's TIFU hyper-parameters (small k for tiny corpora)."""
    return TifuParams(n_items=cfg.n_items, group_size=3,
                      k_neighbors=4)


def _gen_stream(rng, n_users, n_events, n_items, max_baskets,
                skip=()):
    """One randomized interleaved add/del_basket/del_item stream."""
    events, nb = [], [0] * n_users
    for _ in range(n_events):
        u = int(rng.integers(0, n_users))
        if u in skip:
            continue
        r = rng.random()
        if nb[u] > 0 and r < 0.25:
            pos = int(rng.integers(0, nb[u]))
            if r < 0.15:
                events.append(Event(KIND_DEL_BASKET, u, pos=pos))
                nb[u] -= 1
            else:
                events.append(Event(
                    KIND_DEL_ITEM, u, pos=pos,
                    item=int(rng.integers(0, n_items))))
        else:
            items = rng.choice(n_items, size=int(rng.integers(1, 5)),
                               replace=False)
            events.append(Event(KIND_ADD_BASKET, u,
                                items=items.tolist()))
            nb[u] = min(nb[u] + 1, max_baskets)
    return events


def _build_engine(cfg: ComplianceConfig, params, n_shards: int):
    """A fresh single or sharded engine at the sweep's store shapes."""
    if n_shards == 1:
        store = StateStore(StoreConfig(
            n_users=cfg.n_users, n_items=params.n_items,
            max_baskets=cfg.max_baskets,
            max_basket_size=cfg.max_basket_size))
        return StreamingEngine(store, params)
    return ShardedStreamingEngine.create(
        UserShardSpec(n_users=cfg.n_users, n_shards=n_shards), params,
        max_baskets=cfg.max_baskets,
        max_basket_size=cfg.max_basket_size)


def bench_certification(cfg: ComplianceConfig):
    """Phase 1: certify ``n_streams`` randomized deletion-burst streams.

    Alternates 1- and 2-shard engines, forgets one random user per
    stream, runs the checkpoint round-trip check on every
    ``checkpoint_every``-th stream, and ends with the tampered-stream
    canary (a skipped deletion that certify must flag).  Raises if any
    stream fails to certify or the canary goes undetected.
    """
    params = _params(cfg)
    results, passed = [], 0
    for i in range(cfg.n_streams):
        rng = np.random.default_rng(1000 + i)
        t0 = time.perf_counter()
        eng = _build_engine(cfg, params, n_shards=1 + (i % 2))
        events = _gen_stream(rng, cfg.n_users, cfg.n_events,
                             params.n_items, cfg.max_baskets)
        eng.submit(events)
        eng.run_until_drained()
        victim = int(rng.integers(0, cfg.n_users))
        receipt = eng.forget_user(victim)
        dels = [Event(KIND_DEL_BASKET, victim, pos=p)
                for p in range(receipt.n_baskets_deleted - 1, -1, -1)]
        if i % cfg.checkpoint_every == 0:
            with tempfile.TemporaryDirectory() as d:
                report = certify(eng, events + dels,
                                 forgotten_users=[victim],
                                 checkpoint_dir=d)
        else:
            report = certify(eng, events + dels,
                             forgotten_users=[victim])
        ok = report.compliant and receipt.clean
        passed += ok
        if not ok:
            raise AssertionError(
                f"stream {i} failed certification:\n{report.summary()}")
        results.append({
            "phase": "certify", "stream": i,
            "shards": 1 + (i % 2), "forgotten_user": victim,
            "compliant": bool(report.compliant),
            "receipt_clean": bool(receipt.clean),
            "envelope_slack": report.envelope_slack,
            "overlap_mean": report.overlap_mean,
            "wall_s": time.perf_counter() - t0})
    # tampered canary: drop one deletion from the delivered stream —
    # the engine state then differs from the retained-only fit and the
    # structural check MUST flag it
    rng = np.random.default_rng(7)
    events = _gen_stream(rng, cfg.n_users, cfg.n_events, params.n_items,
                         cfg.max_baskets)
    skipped = next(e for e in events if e.kind == KIND_DEL_BASKET)
    eng = _build_engine(cfg, params, n_shards=1)
    eng.submit([e for e in events if e is not skipped])
    eng.run_until_drained()
    detected = not certify(eng, events).compliant
    if not detected:
        raise AssertionError("tampered stream (skipped deletion) was "
                             "NOT detected")
    overlap = [r["overlap_mean"] for r in results]
    summary = {
        "certified_streams_swept": float(passed),
        "certified_violations_detected": 1.0,
        "certify_topn_overlap_mean": float(np.mean(overlap)),
        "certify_envelope_slack_max_over_sweep": float(
            max(r["envelope_slack"] for r in results)),
    }
    return results, summary


def _percentiles(samples_ms):
    """(p50, p95, p99) of a latency sample list, in milliseconds."""
    a = np.asarray(samples_ms, np.float64)
    return (float(np.percentile(a, 50)), float(np.percentile(a, 95)),
            float(np.percentile(a, 99)))


def bench_deletion_latency(cfg: ComplianceConfig):
    """Phase 2: deletion/forget latency percentiles under serve load.

    One engine, ``latency_users`` prefilled users; then
    ``latency_deletions`` randomized basket/item deletions are
    submitted and drained ONE AT A TIME (the per-request latency a
    deletion SLA is written against), with a ``recommend`` batch every
    ``serve_every`` deletions, and ``latency_forgets`` full user-level
    forgets at the end.
    """
    params = _params(cfg)
    store = StateStore(StoreConfig(
        n_users=cfg.latency_users, n_items=params.n_items,
        max_baskets=cfg.max_baskets,
        max_basket_size=cfg.max_basket_size))
    eng = StreamingEngine(store, params)
    rng = np.random.default_rng(42)
    nb = [0] * cfg.latency_users
    for u in range(cfg.latency_users):
        for _ in range(cfg.latency_prefill):
            items = rng.choice(params.n_items,
                               size=int(rng.integers(1, 5)),
                               replace=False)
            eng.submit([Event(KIND_ADD_BASKET, u,
                              items=items.tolist())])
            nb[u] += 1
    eng.run_until_drained()
    eng.store.corpus()                 # warm the serving cache
    eng.recommend(np.arange(cfg.serve_batch), topn=5)
    # warm the single-event deletion programs: the first del_basket /
    # del_item each trigger a jit compile that would otherwise land in
    # the timed tail and report compile cost as deletion latency
    eng.submit([Event(KIND_DEL_BASKET, 0, pos=nb[0] - 1)])
    eng.run_until_drained()
    nb[0] -= 1
    eng.submit([Event(KIND_DEL_ITEM, 0, pos=0,
                      item=int(rng.integers(0, params.n_items)))])
    eng.run_until_drained()

    del_ms, serve_s, serves = [], 0.0, 0
    for i in range(cfg.latency_deletions):
        u = int(rng.integers(0, cfg.latency_users))
        if nb[u] == 0:
            continue
        pos = int(rng.integers(0, nb[u]))
        if rng.random() < 0.5:
            ev = Event(KIND_DEL_BASKET, u, pos=pos)
            nb[u] -= 1
        else:
            ev = Event(KIND_DEL_ITEM, u, pos=pos,
                       item=int(rng.integers(0, params.n_items)))
        t0 = time.perf_counter()
        eng.submit([ev])
        eng.run_until_drained()
        del_ms.append((time.perf_counter() - t0) * 1e3)
        if i % cfg.serve_every == 0:
            users = rng.integers(0, cfg.latency_users, cfg.serve_batch)
            t0 = time.perf_counter()
            eng.recommend(users, topn=5)
            serve_s += time.perf_counter() - t0
            serves += cfg.serve_batch
    forget_ms = []
    for u in range(cfg.latency_forgets):
        receipt = eng.forget_user(u)
        assert receipt.clean, f"forget_user({u}) left residue: " \
            f"{receipt.residue}"
        forget_ms.append(receipt.latency_s * 1e3)
    d50, d95, d99 = _percentiles(del_ms)
    f50, f95, f99 = _percentiles(forget_ms)
    summary = {
        "deletion_p50_ms": d50, "deletion_p95_ms": d95,
        "deletion_p99_ms": d99,
        "forget_p50_ms": f50, "forget_p95_ms": f95,
        "forget_p99_ms": f99,
        "deletion_p99_over_slo": d99 / cfg.deletion_slo_ms,
        "forget_p99_over_slo": f99 / cfg.forget_slo_ms,
        "serve_qps_under_burst": serves / serve_s if serve_s else 0.0,
    }
    results = [{"phase": "latency", "deletions": len(del_ms),
                "forgets": len(forget_ms), "serves": serves,
                **summary}]
    return results, summary


def bench_drift(cfg: ComplianceConfig):
    """Phase 3: Recall/NDCG drift on retained users after a burst.

    Fits a synthetic dataset through the engine's add path, scores the
    held-out baskets via ``table2_predictive.evaluate``, then applies a
    deletion burst — ``drift_user_frac`` of users lose
    ``drift_basket_frac`` of their training baskets, ``drift_forgets``
    users are forgotten outright — and scores the RETAINED users again
    with the same path.  Drift is signed (after − before): deletions
    remove genuine signal, so small negative recall drift is the
    expected cost of compliance, and the numbers quantify it.
    """
    ds = synthetic.generate(cfg.drift_dataset, scale=cfg.drift_scale,
                            seed=cfg.drift_seed)
    p = ds.params
    train, test = ds.train_test_split()
    users = sorted(train)
    max_nb = max(len(b) for b in train.values())
    max_bs = max(max(len(x) for x in bs) for bs in train.values())
    store = StateStore(StoreConfig(
        n_users=len(users), n_items=p.n_items,
        max_baskets=max(max_nb + 1, 4),
        max_basket_size=max(max_bs, 2)))
    eng = StreamingEngine(store, p)
    for u in users:
        for b in train[u]:
            eng.submit([Event(KIND_ADD_BASKET, u, items=list(b))])
    eng.run_until_drained()

    rng = np.random.default_rng(cfg.drift_seed + 1)
    n_burst = max(1, int(len(users) * cfg.drift_user_frac))
    burst_users = rng.choice(len(users), size=n_burst, replace=False)
    forgotten = set(int(u) for u in burst_users[:cfg.drift_forgets])
    retained = [u for u in range(len(users)) if u not in forgotten]
    # before/after are scored on the SAME retained-user set, so the
    # drift isolates the burst's effect from population change
    before = table2_predictive.evaluate(
        np.asarray(eng.store.corpus())[retained],
        [users[u] for u in retained], test, p)
    nb = {u: len(train[users[u]]) for u in range(len(users))}
    for u in burst_users:
        u = int(u)
        if u in forgotten:
            continue
        n_del = max(1, int(nb[u] * cfg.drift_basket_frac))
        for _ in range(n_del):
            if nb[u] == 0:
                break
            eng.submit([Event(KIND_DEL_BASKET, u,
                              pos=int(rng.integers(0, nb[u])))])
            nb[u] -= 1
    eng.run_until_drained()
    for u in sorted(forgotten):
        receipt = eng.forget_user(u)
        assert receipt.clean
    after = table2_predictive.evaluate(
        np.asarray(eng.store.corpus())[retained],
        [users[u] for u in retained], test, p)
    summary = {
        "recall10_drift": after["recall@10"] - before["recall@10"],
        "recall20_drift": after["recall@20"] - before["recall@20"],
        "ndcg10_drift": after["ndcg@10"] - before["ndcg@10"],
        "ndcg20_drift": after["ndcg@20"] - before["ndcg@20"],
    }
    results = [{"phase": "drift", "n_users": len(users),
                "burst_users": int(n_burst),
                "forgotten": sorted(forgotten),
                "before": before, "after": after, **summary}]
    return results, summary


def main() -> int:
    """CLI entry: run the three phases, merge one compliance entry."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep (CI smoke: seconds on CPU)")
    ap.add_argument("--backend", choices=sorted(BACKEND_IMPL),
                    default=None,
                    help="kernel path to exercise (default: tpu on a "
                         "TPU host, else cpu)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_updates.json"))
    args = ap.parse_args()
    cfg = SMOKE if args.smoke else ComplianceConfig()
    backend = args.backend or ("tpu" if jax.default_backend() == "tpu"
                               else "cpu")
    if backend == "tpu" and jax.default_backend() != "tpu":
        ap.error("--backend tpu requires a TPU host "
                 f"(jax.default_backend() == {jax.default_backend()!r})")
    if backend == "interpret" and not args.smoke:
        ap.error("--backend interpret is interpret-mode Pallas: only "
                 "allowed with --smoke")

    results, summary = [], {}
    with ops.default_impl(BACKEND_IMPL[backend]):
        for phase in (bench_certification, bench_deletion_latency,
                      bench_drift):
            r, s = phase(cfg)
            results.extend(r)
            summary.update(s)

    print(f"\nsummary [{backend}]:")
    for k, v in summary.items():
        note = ""
        if k.endswith("_over_slo"):
            note = "  (acceptance: <= 1.0)"
        elif k == "certified_streams_swept":
            note = f"  (acceptance: == {cfg.n_streams})"
        print(f"  {k}: {v:.4f}{note}" if isinstance(v, float)
              else f"  {k}: {v}")

    entry = {
        "backend": backend,
        "jax_backend": jax.default_backend(),
        "mode": "smoke" if args.smoke else "full",
        "arm": "compliance",
        "config": dataclasses.asdict(cfg),
        "summary": summary,
        "results": results,
    }
    out = os.path.abspath(args.out)
    payload = merge_runs(out, entry)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"\nwrote {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
