"""Shared building blocks for the non-LM model zoo."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def init_mlp(key, dims, dtype=jnp.float32, bias=True):
    """dims = [in, h1, ..., out]. Returns list of {"w","b"} dicts."""
    layers = []
    keys = jax.random.split(key, len(dims) - 1)
    for k, (a, b) in zip(keys, zip(dims[:-1], dims[1:])):
        w = (jax.random.normal(k, (a, b), jnp.float32)
             * np.sqrt(2.0 / a)).astype(dtype)
        layers.append({"w": w, "b": jnp.zeros((b,), dtype)} if bias
                      else {"w": w})
    return layers


def apply_mlp(layers, x, act=jax.nn.relu, final_act=None):
    for i, l in enumerate(layers):
        x = x @ l["w"] + (l.get("b", 0.0))
        if i < len(layers) - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


def mlp_shapes(dims, bias=True):
    out = []
    for a, b in zip(dims[:-1], dims[1:]):
        out.append({"w": (a, b), "b": (b,)} if bias else {"w": (a, b)})
    return out


def bce_with_logits(logits, labels):
    """Binary cross-entropy on logits (f32 accumulation)."""
    z = logits.astype(jnp.float32)
    y = labels.astype(jnp.float32)
    return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))


def map_batch_chunks(fn, batch: dict, chunk: int, keys=None):
    """Run ``fn(sub_batch)`` over batch chunks with lax.map and re-stack.

    Bounds serve-time transients (attention scores, gathers) for the
    offline bulk-scoring cells.  ``keys``: which batch entries carry the
    batch dim (default: all)."""
    keys = list(batch) if keys is None else keys
    b = batch[keys[0]].shape[0]
    if b <= chunk or b % chunk:
        return fn(batch)
    n = b // chunk
    split = dict(batch)
    for k in keys:
        split[k] = batch[k].reshape(n, chunk, *batch[k].shape[1:])
    rest = {k: v for k, v in batch.items() if k not in keys}

    def body(sub):
        return fn({**{k: sub[k] for k in keys}, **rest})

    out = jax.lax.map(body, {k: split[k] for k in keys})
    return jax.tree.map(lambda x: x.reshape(n * chunk, *x.shape[2:]), out)


def layer_norm(x, w, b, eps=1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w + b
