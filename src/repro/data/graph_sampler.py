"""Graph data pipeline: CSR neighbour sampling (GraphSAGE-style layered
fanout) and partition-aware DimeNet triplet construction.

``minibatch_lg`` requires a REAL neighbour sampler (assignment note) —
``LayeredSampler.sample`` draws a seed batch and fans out per layer from
a CSR adjacency.  ``build_triplets`` emits (tri_kj, tri_ji, angle) lists
whose edges are partition-local (both edges of a triplet fall in the
same edge-range partition; cross-partition angles are dropped — the
documented approximation that keeps distributed message passing local,
DESIGN.md §5)."""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray     # [N+1]
    indices: np.ndarray    # [E]
    n_nodes: int

    @staticmethod
    def random(n_nodes: int, avg_degree: int, seed: int = 0) -> "CSRGraph":
        rng = np.random.default_rng(seed)
        degrees = rng.poisson(avg_degree, n_nodes).clip(1)
        indptr = np.concatenate([[0], np.cumsum(degrees)])
        indices = rng.integers(0, n_nodes, indptr[-1])
        return CSRGraph(indptr.astype(np.int64), indices.astype(np.int64),
                        n_nodes)


class LayeredSampler:
    """Uniform fanout sampling (fanouts like [15, 10])."""

    def __init__(self, graph: CSRGraph, fanouts, seed: int = 0):
        self.g = graph
        self.fanouts = list(fanouts)
        self.rng = np.random.default_rng(seed)

    def sample(self, seeds: np.ndarray):
        """Returns (sub_src, sub_dst, node_map) for the sampled subgraph;
        edges are directed neighbour→target per GraphSAGE layer."""
        src_all, dst_all = [], []
        frontier = np.unique(seeds)
        nodes = [frontier]
        for fan in self.fanouts:
            s_list, d_list = [], []
            for v in frontier:
                lo, hi = self.g.indptr[v], self.g.indptr[v + 1]
                nbrs = self.g.indices[lo:hi]
                if len(nbrs) == 0:
                    continue
                take = self.rng.choice(nbrs, size=min(fan, len(nbrs)),
                                       replace=False)
                s_list.append(take)
                d_list.append(np.full(len(take), v))
            if not s_list:
                break
            s = np.concatenate(s_list)
            d = np.concatenate(d_list)
            src_all.append(s)
            dst_all.append(d)
            frontier = np.unique(s)
            nodes.append(frontier)
        src = np.concatenate(src_all) if src_all else np.zeros(0, np.int64)
        dst = np.concatenate(dst_all) if dst_all else np.zeros(0, np.int64)
        node_map = np.unique(np.concatenate(nodes))
        return src, dst, node_map


def build_triplets(src: np.ndarray, dst: np.ndarray, n_partitions: int = 1,
                   max_per_edge: int = 8, seed: int = 0
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """DimeNet triplets: for edge e=(j→i), feeder edges f=(k→j).

    Returns LOCAL edge indices (tri_kj, tri_ji) per partition concatenated
    — both ends of each triplet lie in the same edge-id partition
    (partition-aware sampling), so the distributed gather stays local.
    """
    rng = np.random.default_rng(seed)
    n_edges = len(src)
    part = max(n_edges // n_partitions, 1)
    tri_kj, tri_ji = [], []
    for p in range(n_partitions):
        lo, hi = p * part, min((p + 1) * part, n_edges)
        if lo >= hi:
            break
        # edges into each node within this partition
        by_dst = {}
        for e in range(lo, hi):
            by_dst.setdefault(dst[e], []).append(e)
        for e in range(lo, hi):
            feeders = by_dst.get(src[e], [])
            feeders = [f for f in feeders if f != e]
            if not feeders:
                continue
            take = feeders if len(feeders) <= max_per_edge else \
                list(rng.choice(feeders, size=max_per_edge, replace=False))
            for f in take:
                tri_kj.append(f - lo)   # local index within partition
                tri_ji.append(e - lo)
    return (np.asarray(tri_kj, np.int32), np.asarray(tri_ji, np.int32))


def pad_to(x: np.ndarray, n: int, fill=0):
    out = np.full((n,) + x.shape[1:], fill, dtype=x.dtype)
    out[:len(x)] = x[:n]
    return out


def molecule_batch(n_graphs: int, nodes_per_graph: int = 30,
                   edges_per_graph: int = 64, seed: int = 0):
    """Batched small molecules: positions → a flat graph with offsets."""
    rng = np.random.default_rng(seed)
    z, pos, src, dst, graph_id = [], [], [], [], []
    off = 0
    for g in range(n_graphs):
        z.append(rng.integers(1, 10, nodes_per_graph))
        pos.append(rng.normal(scale=2.0, size=(nodes_per_graph, 3)))
        s = rng.integers(0, nodes_per_graph, edges_per_graph) + off
        d = rng.integers(0, nodes_per_graph, edges_per_graph) + off
        src.append(s)
        dst.append(d)
        graph_id.append(np.full(nodes_per_graph, g))
        off += nodes_per_graph
    return (np.concatenate(z).astype(np.int32),
            np.concatenate(pos).astype(np.float32),
            np.concatenate(src).astype(np.int32),
            np.concatenate(dst).astype(np.int32),
            np.concatenate(graph_id).astype(np.int32))
