#!/usr/bin/env python
"""Kernel-contract + engine-invariant linter CLI (DESIGN.md §10).

Usage::

    python tools/lint_kernels.py             # lint the repo, human output
    python tools/lint_kernels.py --json out.json
    python tools/lint_kernels.py --selftest  # run the seeded-bad corpus

Exit status is 0 only when the repo lints clean (or, with --selftest,
when every corpus case is flagged with its expected rule ids).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis import corpus as corpus_mod  # noqa: E402
from repro.analysis import linter  # noqa: E402


def _selftest(root: Path) -> int:
    results = corpus_mod.run_corpus(root / "tests" / "analysis_corpus")
    for r in results:
        print(r)
    bad = [r for r in results if not r.ok]
    print(f"{len(results) - len(bad)}/{len(results)} corpus cases ok")
    return 1 if bad else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", type=Path, default=ROOT,
                    help="repo root to lint (default: this checkout)")
    ap.add_argument("--json", type=Path, default=None, metavar="PATH",
                    help="also write the machine-readable report here")
    ap.add_argument("--selftest", action="store_true",
                    help="run the seeded-violation corpus instead")
    args = ap.parse_args(argv)

    if args.selftest:
        return _selftest(args.root)

    report = linter.lint_repo(args.root)
    if args.json is not None:
        args.json.write_text(report.to_json() + "\n")
    for finding in report.findings:
        print(finding)
    counts = report.counts()
    if report.ok:
        print("lint_kernels: clean "
              f"({len(linter.REGISTRY)} contracts checked)")
        return 0
    print(f"lint_kernels: {len(report.findings)} finding(s) {counts}")
    return 1


if __name__ == "__main__":
    raise SystemExit(main())
