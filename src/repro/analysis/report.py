"""Findings and the machine-readable lint report (DESIGN.md §10).

A :class:`Finding` is one rule violation anchored to a file/line; a
:class:`Report` is the full result of a lint run and serializes to the
JSON document ``tools/lint_kernels.py --json`` emits (and CI archives).
"""
from __future__ import annotations

import dataclasses
import json
from typing import List

# Rule-id -> one-line description (the authoritative rule list; §10).
RULES = {
    "KC01": "pallas_call site without a registered KernelContract "
            "(or a stale contract with no surviving site)",
    "KC02": "grid rank / scalar-prefetch count does not match the "
            "contract or the BlockSpec index-map arities",
    "KC03": "analytic VMEM model missing, inconsistent with max_shapes, "
            "or over the 16 MiB budget at declared max shapes",
    "KC04": "non-divisible (cdiv) grid axis without a tail mask, or a "
            "divisibility contract without an enforcing assert",
    "KC05": "kernel-body dot without an explicit f32/i32 accumulation "
            "dtype (preferred_element_type)",
    "KC06": "float64 reference inside a kernel module",
    "KC07": "approximate transcendental in an exact-parity kernel body",
    "KC08": "VMEM scratch accumulator dtypes do not match the contract "
            "(running accumulators must be f32/i32)",
    "OR01": "ops.py dispatcher with no reachable kernels/ref.py oracle",
    "OR02": "dispatcher/oracle pair never exercised together by a test",
    "OR03": "intentional duplicate pair has drifted (AST-normalized "
            "bodies differ)",
    "EN01": "state-store write path does not reach the atomic commit "
            "primitive (atomic_write_json)",
    "EN02": "fault-site registry not closed (unknown trip site, or a "
            "registered site with no hook)",
    "EN03": "BENCH summary key matches neither the gated nor the "
            "parity naming convention",
}


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation: ``rule`` id, location, human message."""

    rule: str
    path: str
    line: int
    message: str

    def to_dict(self) -> dict:
        """Plain-dict form for the JSON report."""
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message,
                "description": RULES.get(self.rule, "")}

    def __str__(self) -> str:
        """``path:line: RULE message`` (compiler-style)."""
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


@dataclasses.dataclass
class Report:
    """A full lint run: sorted findings plus per-family counts."""

    findings: List[Finding] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        """True when the run produced no findings."""
        return not self.findings

    def counts(self) -> dict:
        """Finding count per rule id (only rules that fired)."""
        out: dict = {}
        for f in self.findings:
            out[f.rule] = out.get(f.rule, 0) + 1
        return out

    def to_json(self) -> str:
        """The machine-readable report document."""
        return json.dumps({
            "ok": self.ok,
            "counts": self.counts(),
            "findings": [f.to_dict() for f in sorted(self.findings)],
        }, indent=2, sort_keys=True)
