"""Production meshes. v5e-256 per pod: single-pod (16,16) = 256 chips,
multi-pod (2,16,16) = 512 chips.  A FUNCTION so importing this module
never touches jax device state (dryrun sets the device-count env first).
"""
from __future__ import annotations

import jax

from repro.parallel.sharding import ShardingRules


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_rules(mesh=None) -> ShardingRules:
    """Default sharding rules: batch/FSDP over (pod,data), TP over model."""
    return ShardingRules(batch=("pod", "data"), fsdp=("pod", "data"),
                         tensor="model", expert="model", context="model")


def make_test_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for CI-size dry-runs (subprocess tests)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_user_shard_meshes(n_shards: int, devices=None):
    """One ``("data", "model")`` mesh per user shard (DESIGN.md §7).

    The sharded streaming engine runs one independent `StateStore` +
    exactly-once log per user shard; each shard's arrays live on its own
    device group.  Devices are dealt round-robin so shard ``s`` gets
    ``devices[s::n_shards]``; on hosts with fewer devices than shards
    (the CPU test runner: one device) shards share devices — the layout
    is then logical only, but every code path is identical to the
    multi-host one.

    Each mesh keeps a size-1 ``"model"`` axis so `StoreConfig`'s default
    ``item_axes=("model",)`` placement resolves without a special case.
    """
    from jax.sharding import Mesh
    import numpy as np
    if devices is None:
        devices = jax.devices()
    devices = list(devices)
    groups = [devices[s::n_shards] or [devices[s % len(devices)]]
              for s in range(n_shards)]
    return [Mesh(np.asarray(g).reshape(len(g), 1), ("data", "model"))
            for g in groups]


# Hardware constants for the roofline model (TPU v5e).
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link (~per chip per direction)
HBM_PER_CHIP = 16 * 2 ** 30    # 16 GiB
