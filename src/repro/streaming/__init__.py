"""Streaming: micro-batch state maintenance (Spark Structured Streaming
analog — paper §5), exactly-once recovery, stability-triggered refresh,
the user-axis sharded deployment (DESIGN.md §7), and the durable
ingestion / fault-injection layer (DESIGN.md §9)."""
from repro.streaming.async_checkpoint import AsyncCheckpointer
from repro.streaming.engine import (AdmissionResult, Backpressure, Event,
                                    ForgetReceipt, InvalidEventError,
                                    ShardedStreamingEngine,
                                    StreamingEngine)
from repro.streaming.state_store import (CorruptCheckpointError, StateStore,
                                         StoreConfig,
                                         load_checkpoint_arrays,
                                         load_json_checked, state_shardings,
                                         with_io_retries)

__all__ = ["AsyncCheckpointer",
           "Event", "ForgetReceipt", "StreamingEngine",
           "ShardedStreamingEngine",
           "StateStore", "StoreConfig", "state_shardings",
           "load_checkpoint_arrays", "AdmissionResult", "Backpressure",
           "InvalidEventError", "CorruptCheckpointError",
           "load_json_checked", "with_io_retries"]
