"""Durable ingestion + recovery hardening (DESIGN.md §9): eager event
validation, dead-letter quarantine, backpressure shedding without event
loss, checksum-verified commits with fallback to the last good pair,
bounded I/O retries, degraded serving, and the sharded restore
diagnostics.  The systematic crash/corruption sweep lives in
test_chaos_soak.py."""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.core import RefEngine, TifuParams
from repro.core.types import (KIND_ADD_BASKET, KIND_DEL_BASKET,
                              KIND_DEL_ITEM)
from repro.parallel.sharding import UserShardSpec
from repro.streaming import (AdmissionResult, Backpressure,
                             CorruptCheckpointError, Event,
                             InvalidEventError, ShardedStreamingEngine,
                             StateStore, StoreConfig, StreamingEngine,
                             with_io_retries)
from repro.streaming import faults

P = TifuParams(n_items=29, group_size=3)
M = 8           # users
NB, BS = 24, 6  # max_baskets, max_basket_size


def make_engine(n_users=M, batch_size=16, **kw):
    store = StateStore(StoreConfig(n_users=n_users, n_items=P.n_items,
                                   max_baskets=NB, max_basket_size=BS))
    return StreamingEngine(store, P, batch_size=batch_size, **kw), store


def make_sharded(n_shards, **kw):
    return ShardedStreamingEngine.create(
        UserShardSpec(M, n_shards), P, max_baskets=NB, max_basket_size=BS,
        batch_size=16, **kw)


def add_events(rng, n, n_users=M, start_seqno=0):
    """n valid add-basket events with explicit consecutive seqnos."""
    return [Event(KIND_ADD_BASKET, int(rng.integers(0, n_users)),
                  items=rng.choice(P.n_items, size=3,
                                   replace=False).astype(np.int32),
                  seqno=start_seqno + i)
            for i in range(n)]


def vecs(store):
    return np.asarray(store.state.materialized_user_vecs())


# ---------------------------------------------------------------------------
# Satellite 1: eager submit validation
# ---------------------------------------------------------------------------

BAD_EVENTS = [
    (Event(99, 0, items=np.array([1], np.int32)), "unknown event kind"),
    (Event(KIND_ADD_BASKET, -1, items=np.array([1], np.int32)), "user -1"),
    (Event(KIND_ADD_BASKET, M, items=np.array([1], np.int32)), f"user {M}"),
    (Event(KIND_ADD_BASKET, 0, items=np.array([], np.int32)), "no items"),
    (Event(KIND_ADD_BASKET, 0, items=np.array([P.n_items], np.int32)),
     f"item id {P.n_items}"),
    (Event(KIND_ADD_BASKET, 0, items=np.array([-2], np.int32)),
     "item id -2"),
    (Event(KIND_ADD_BASKET, 0, items=np.zeros(BS + 1, np.int32)),
     "exceeds max_basket_size"),
    (Event(KIND_DEL_BASKET, 0, pos=-1), "position -1"),
    (Event(KIND_DEL_BASKET, 0, pos=NB), f"position {NB}"),
    (Event(KIND_DEL_ITEM, 0, pos=0, item=P.n_items),
     f"item id {P.n_items}"),
]


@pytest.mark.parametrize("ev,match", BAD_EVENTS,
                         ids=[m for _, m in BAD_EVENTS])
def test_submit_rejects_malformed_events_eagerly(ev, match):
    eng, _ = make_engine()
    with pytest.raises(InvalidEventError, match=match):
        eng.submit([ev])
    assert eng.n_pending == 0           # nothing was half-admitted
    assert eng.metrics.dead_letters == 0


def test_poison_events_quarantine_while_valid_ones_drain(rng):
    """on_invalid='quarantine': poison lands in the dead-letter queue
    (with its reason), the engine drains the rest, and the quarantined
    seqno is consumed — a replay dedups it instead of re-poisoning."""
    eng, store = make_engine()
    ref = RefEngine(P, dtype=np.float32)
    events = add_events(rng, 12)
    for ev in events:
        ref.add_basket(ev.user, ev.items)
    poison = [Event(KIND_ADD_BASKET, M + 5,
                    items=np.array([1], np.int32), seqno=12),
              Event(KIND_DEL_ITEM, 0, pos=0, item=-3, seqno=13)]
    res = eng.submit(events + poison, on_invalid="quarantine")
    assert (res.admitted, res.quarantined, res.rejected) == (12, 2, 0)
    assert eng.metrics.dead_letters == 2
    reasons = [r for _, r in eng.dead_letter]
    assert "user 13 outside" in reasons[0]
    assert "item id -3" in reasons[1]
    assert eng.run_until_drained() == 12
    np.testing.assert_allclose(
        vecs(store), np.stack([ref.state(u).user_vec.astype(np.float32)
                               for u in range(M)]), atol=1e-4)
    # the whole stream (poison included) is behind the watermark now
    assert eng.watermark == 13
    replay = eng.submit(events + poison, on_invalid="quarantine")
    assert replay.deduped == 14 and replay.quarantined == 0
    assert eng.metrics.dead_letters == 2    # not re-quarantined


def test_apply_time_delete_quarantine_instead_of_wrong_basket(rng):
    """A delete position beyond the user's CURRENT history passes the
    static check but would be clipped onto the WRONG basket by the
    applier — it must quarantine at apply time, leaving state exactly as
    if the event never existed, while its seqno still advances the log."""
    eng, store = make_engine()
    ref = RefEngine(P, dtype=np.float32)
    baskets = [rng.choice(P.n_items, size=3, replace=False) for _ in range(3)]
    for b in baskets:
        eng.add_basket(2, b)
        ref.add_basket(2, b)
    eng.run_until_drained()
    # pos=7 < max_baskets (static-valid) but user 2 has only 3 baskets
    eng.submit([Event(KIND_DEL_BASKET, 2, pos=7, seqno=3),
                Event(KIND_ADD_BASKET, 4, seqno=4,
                      items=np.asarray(baskets[0], np.int32))])
    eng.run_until_drained()
    assert eng.metrics.dead_letters == 1
    _, reason = eng.dead_letter[0]
    assert "beyond user 2's history of 3 basket(s)" in reason
    assert int(store.state.n_baskets[2]) == 3      # nothing deleted
    ref.add_basket(4, baskets[0])
    np.testing.assert_allclose(vecs(store)[2],
                               ref.state(2).user_vec.astype(np.float32),
                               atol=1e-4)
    assert eng.watermark == 4                      # poison seqno consumed
    res = eng.submit([Event(KIND_DEL_BASKET, 2, pos=7, seqno=3)])
    assert res.deduped == 1                        # replay skips it


# ---------------------------------------------------------------------------
# Backpressure: bounded queues, deterministic shedding, no event loss
# ---------------------------------------------------------------------------

def test_backpressure_raises_after_admitting_prefix(rng):
    eng, _ = make_engine(max_pending=4)
    events = add_events(rng, 10)
    with pytest.raises(Backpressure) as ei:
        eng.submit(events)
    assert ei.value.admitted == 4
    assert ei.value.rejected == 6
    assert ei.value.first_rejected_seqno == 4
    assert eng.n_pending == 4                      # prefix stays admitted
    assert eng.metrics.backpressure_rejections == 6


def test_shed_suffix_until_first_rejected_seqno_readmitted(rng):
    """Once seqno s is shed, seqnos above s keep shedding EVEN AFTER the
    queues drain: admitting them would open a permanent gap at s, roll
    the watermark past it, and turn s's redelivery into a dropped
    'duplicate' (a lost event).  Readmitting s reopens admission, and
    the replayed suffix converges to the no-fault state."""
    eng, store = make_engine(max_pending=4)
    ref = RefEngine(P, dtype=np.float32)
    events = add_events(rng, 10)
    for ev in events:
        ref.add_basket(ev.user, ev.items)
    res = eng.submit(events, on_overflow="shed")
    assert (res.admitted, res.rejected) == (4, 6)
    eng.run_until_drained()
    # queues empty, but seqno 5 alone must still shed (4 is the gap)
    res = eng.submit([events[5]], on_overflow="shed")
    assert res.rejected == 1 and eng.n_pending == 0
    # contract-abiding source resends from first_rejected_seqno
    res = eng.submit(events[4:], on_overflow="shed")
    assert res.admitted == 4 and res.rejected == 2     # hit the mark again
    eng.run_until_drained()
    res = eng.submit(events[8:])
    assert res.admitted == 2
    eng.run_until_drained()
    assert eng.watermark == 9
    np.testing.assert_allclose(
        vecs(store), np.stack([ref.state(u).user_vec.astype(np.float32)
                               for u in range(M)]), atol=1e-4)


def test_backpressure_sheds_seqnoless_events_without_burning_seqnos(rng):
    """Auto-seqno sources: shed events never consume a sequence number,
    so resubmitting the same payloads later just works."""
    eng, store = make_engine(max_pending=4)
    ref = RefEngine(P, dtype=np.float32)
    payloads = [rng.choice(P.n_items, size=3, replace=False) for _ in range(9)]
    for b in payloads:
        ref.add_basket(1, b)
    events = [Event(KIND_ADD_BASKET, 1, items=np.asarray(b, np.int32))
              for b in payloads]
    res = eng.submit(events, on_overflow="shed")
    assert (res.admitted, res.rejected) == (4, 5)
    assert res.first_rejected_seqno is None
    assert eng._next_seqno == 4                    # no seqno burned
    eng.run_until_drained()
    res = eng.submit(events[4:][:4], on_overflow="shed")
    assert res.admitted == 4
    eng.run_until_drained()
    assert eng.submit(events[8:]).admitted == 1
    eng.run_until_drained()
    np.testing.assert_allclose(vecs(store)[1],
                               ref.state(1).user_vec.astype(np.float32),
                               atol=1e-4)


def test_sharded_backpressure_aggregates_across_shards(rng):
    """The router probes the owner shard before burning a global seqno;
    rejected events stay seqno-less and a later resubmit drains fine."""
    eng = make_sharded(2, max_pending=2)
    events = [Event(KIND_ADD_BASKET, u % M,
                    items=rng.choice(P.n_items, size=3,
                                     replace=False).astype(np.int32))
              for u in range(12)]
    res = eng.submit(events, on_overflow="shed")
    # 2 shards x max_pending=2 admitted, the rest shed, no seqno burned
    assert (res.admitted, res.rejected) == (4, 8)
    assert eng._next_seqno == 4
    eng.run_until_drained()
    with pytest.raises(Backpressure):
        eng.submit(events)       # default on_overflow="raise"
    eng.run_until_drained()
    res = eng.submit(events[-4:], on_overflow="shed")
    assert res.admitted == 4
    assert eng.backpressure_rejections > 0


def test_sharded_router_quarantines_unroutable_users():
    eng = make_sharded(2)
    bad = Event(KIND_ADD_BASKET, M + 7, items=np.array([1], np.int32))
    with pytest.raises(InvalidEventError, match="global range"):
        eng.submit([bad])
    res = eng.submit([bad], on_invalid="quarantine")
    assert res.quarantined == 1
    assert eng.router_dead_letters == 1 and eng.dead_letters == 1


# ---------------------------------------------------------------------------
# Corruption detection + fallback to the last good commit pair
# ---------------------------------------------------------------------------

def two_commit_dir(rng, tmp_path, n_events=16):
    """Engine with two checkpoints in one dir; returns (events, dir)."""
    eng, _ = make_engine()
    events = add_events(rng, n_events)
    eng.submit(events[:n_events // 2])
    eng.run_until_drained()
    eng.checkpoint(str(tmp_path), 1)
    eng.submit(events[n_events // 2:])
    eng.run_until_drained()
    eng.checkpoint(str(tmp_path), 2)
    return events, eng


@pytest.mark.parametrize("corrupt", ["bitflip_latest", "tear_latest",
                                     "bitflip_npz", "tear_npz"])
def test_corrupt_newest_commit_falls_back_to_prev_pair(rng, tmp_path,
                                                       corrupt):
    """Any corruption of the newest commit (metadata or state payload)
    is caught by its recorded CRC and restore falls back to the previous
    commit PAIR — state and exactly-once log together — after which a
    full replay converges to the no-fault state."""
    events, eng1 = two_commit_dir(rng, tmp_path)
    d = str(tmp_path)
    if corrupt == "bitflip_latest":
        faults.bitflip_file(os.path.join(d, "LATEST"), seed=3)
    elif corrupt == "tear_latest":
        faults.tear_file(os.path.join(d, "LATEST"), keep_frac=0.4)
    elif corrupt == "bitflip_npz":
        faults.bitflip_file(os.path.join(d, "state_0000000002.npz"),
                            seed=3, n_bits=4)
    else:
        faults.tear_file(os.path.join(d, "state_0000000002.npz"),
                         keep_frac=0.5)
    eng2, store2 = make_engine()
    eng2.restore(d)
    assert store2.restore_fallbacks == 1
    assert store2.corruption_detected >= 1
    assert store2.last_restored_meta["_recovery"]["source"] == "LATEST.prev"
    assert eng2.watermark == len(events) // 2 - 1     # commit 1's log
    eng2.submit(events)                               # full replay
    eng2.run_until_drained()
    np.testing.assert_array_equal(vecs(store2), vecs(eng1.store))


def test_both_commits_corrupt_raises_with_all_errors(rng, tmp_path):
    two_commit_dir(rng, tmp_path)
    faults.bitflip_file(os.path.join(str(tmp_path), "LATEST"), seed=1)
    faults.tear_file(os.path.join(str(tmp_path), "LATEST.prev"),
                     keep_frac=0.3)
    eng, _ = make_engine()
    with pytest.raises(CorruptCheckpointError, match="LATEST.prev"):
        eng.restore(str(tmp_path))


def test_legacy_checkpoint_without_crc_fields_still_restores(rng,
                                                             tmp_path):
    """Checkpoints written before the integrity fields existed carry no
    CRCs: they restore unverified rather than being rejected."""
    events, eng1 = two_commit_dir(rng, tmp_path)
    latest = os.path.join(str(tmp_path), "LATEST")
    with open(latest) as f:
        meta = json.load(f)
    for k in ("meta_crc32", "npz_crc32", "npz_bytes"):
        meta.pop(k)
    with open(latest, "w") as f:
        json.dump(meta, f)
    eng2, store2 = make_engine()
    eng2.restore(str(tmp_path))
    assert store2.restore_fallbacks == 0
    np.testing.assert_array_equal(vecs(store2), vecs(eng1.store))


# ---------------------------------------------------------------------------
# Transient I/O errors: bounded retry-with-backoff
# ---------------------------------------------------------------------------

def test_transient_write_errors_absorbed_by_retry_budget(rng, tmp_path):
    eng, store = make_engine()
    eng.submit(add_events(rng, 6))
    eng.run_until_drained()
    plan = faults.FaultPlan(io_errors={"npz.pre_write": 2,
                                       "LATEST.pre_replace": 1})
    with faults.inject(plan):
        eng.checkpoint(str(tmp_path), 1)
    assert store.io_retries == 3
    assert plan.io_errors == {"npz.pre_write": 0, "LATEST.pre_replace": 0}
    eng2, store2 = make_engine()
    eng2.restore(str(tmp_path))                 # commit is fully intact
    np.testing.assert_array_equal(vecs(store2), vecs(store))


def test_retry_budget_exhaustion_surfaces_an_oserror(rng, tmp_path):
    eng, store = make_engine()
    eng.submit(add_events(rng, 4))
    eng.run_until_drained()
    plan = faults.FaultPlan(io_errors={"npz.pre_write": 99})
    with faults.inject(plan):
        with pytest.raises(OSError, match="retry budget exhausted"):
            eng.checkpoint(str(tmp_path), 1)
    assert store.io_retries == store.cfg.io_retries


def test_transient_read_errors_absorbed_on_restore(rng, tmp_path):
    eng, store = make_engine()
    eng.submit(add_events(rng, 6))
    eng.run_until_drained()
    eng.checkpoint(str(tmp_path), 1)
    eng2, store2 = make_engine()
    with faults.inject(faults.FaultPlan(io_errors={"LATEST.read": 2})):
        eng2.restore(str(tmp_path))
    np.testing.assert_array_equal(vecs(store2), vecs(store))


def test_with_io_retries_never_retries_file_not_found(tmp_path):
    calls = []

    def missing():
        calls.append(1)
        raise FileNotFoundError("nope")

    with pytest.raises(FileNotFoundError):
        with_io_retries(missing, "probe")
    assert len(calls) == 1      # deterministic miss, not transient


# ---------------------------------------------------------------------------
# Degraded serving: recommend keeps answering during recovery
# ---------------------------------------------------------------------------

def test_frozen_corpus_serves_stale_answers_until_thaw(rng):
    eng, _ = make_engine()
    eng.submit(add_events(rng, 12))
    eng.run_until_drained()
    users = list(range(M))
    before = eng.recommend(users, topn=4, k=3)
    eng.freeze_serving()
    assert eng.serving_degraded
    eng.submit(add_events(rng, 12, start_seqno=12))
    eng.run_until_drained()
    np.testing.assert_array_equal(eng.recommend(users, topn=4, k=3),
                                  before)       # stale but well-formed
    eng.thaw_serving()
    assert not eng.serving_degraded
    # after thaw: identical to an engine that saw everything live
    ctl, _ = make_engine()
    rng2 = np.random.default_rng(0)
    ctl.submit(add_events(rng2, 12))
    ctl.submit(add_events(rng2, 12, start_seqno=12))
    ctl.run_until_drained()
    np.testing.assert_array_equal(eng.recommend(users, topn=4, k=3),
                                  ctl.recommend(users, topn=4, k=3))


def test_recover_shard_serves_through_recovery_and_failure(rng, tmp_path):
    """recover_shard: success thaws onto the recovered state; a FAILED
    recovery leaves the shard frozen, still answering from the pinned
    snapshot, with the error surfaced to the caller."""
    eng = make_sharded(2)
    events = add_events(rng, 16)
    eng.submit(events[:8])
    eng.run_until_drained()
    eng.checkpoint(str(tmp_path), 1)
    eng.submit(events[8:])
    eng.run_until_drained()
    eng.checkpoint(str(tmp_path), 2)
    users = list(range(M))
    healthy = eng.recommend(users, topn=4, k=3)
    # newest commit of shard 0 corrupted -> recover from the prev pair
    shard_dir = os.path.join(str(tmp_path), "shard_000")
    faults.bitflip_file(os.path.join(shard_dir, "LATEST"), seed=5)
    info = eng.recover_shard(0, str(tmp_path))
    assert info["source"] == "LATEST.prev"
    assert not eng.shards[0].serving_degraded
    eng.submit(events)                     # replay re-applies the delta
    eng.run_until_drained()
    np.testing.assert_array_equal(eng.recommend(users, topn=4, k=3),
                                  healthy)
    # now the whole shard directory is unrecoverable: the shard must
    # stay frozen and cross-shard serving keeps answering
    faults.bitflip_file(os.path.join(shard_dir, "LATEST"), seed=6)
    faults.tear_file(os.path.join(shard_dir, "LATEST.prev"), keep_frac=0.2)
    with pytest.raises(CorruptCheckpointError):
        eng.recover_shard(0, str(tmp_path))
    assert eng.shards[0].serving_degraded
    np.testing.assert_array_equal(eng.recommend(users, topn=4, k=3),
                                  healthy)


# ---------------------------------------------------------------------------
# Satellite 2: sharded restore diagnostics
# ---------------------------------------------------------------------------

def sharded_checkpoint(rng, tmp_path, n_shards=2):
    eng = make_sharded(n_shards)
    eng.submit(add_events(rng, 12))
    eng.run_until_drained()
    eng.checkpoint(str(tmp_path), 1)
    return eng


def test_restore_names_the_missing_shard_directory(rng, tmp_path):
    sharded_checkpoint(rng, tmp_path)
    import shutil
    shutil.rmtree(os.path.join(str(tmp_path), "shard_001"))
    eng = make_sharded(2)
    with pytest.raises(FileNotFoundError,
                       match=r"missing commit\(s\) in: .*shard_001"):
        eng.restore(str(tmp_path))


def test_restore_names_a_partial_shard_directory(rng, tmp_path):
    """A shard dir that exists but lost its commit files is 'partial';
    the diagnostic must name it and the expected layout."""
    sharded_checkpoint(rng, tmp_path)
    d = os.path.join(str(tmp_path), "shard_000")
    os.remove(os.path.join(d, "LATEST"))
    eng = make_sharded(2)
    with pytest.raises(FileNotFoundError, match="shard_000 … shard_001"):
        eng.restore(str(tmp_path))


def test_restore_reports_torn_manifest(rng, tmp_path):
    sharded_checkpoint(rng, tmp_path)
    faults.tear_file(os.path.join(str(tmp_path), "SHARDS"), keep_frac=0.5)
    eng = make_sharded(2)
    with pytest.raises(CorruptCheckpointError, match="manifest"):
        eng.restore(str(tmp_path))


def test_checkpoint_refuses_directory_with_corrupt_manifest(rng,
                                                            tmp_path):
    eng = sharded_checkpoint(rng, tmp_path)
    faults.tear_file(os.path.join(str(tmp_path), "SHARDS"), keep_frac=0.6)
    with pytest.raises(CorruptCheckpointError, match="refusing to commit"):
        eng.checkpoint(str(tmp_path), 2)


def test_bitflip_to_invalid_utf8_reads_as_corruption(rng, tmp_path):
    """A bit flip that breaks the file's UTF-8 encoding must surface as
    CorruptCheckpointError, not a raw UnicodeDecodeError (regression).
    Note the one blind spot, accepted by design: a flip that knocks out
    the ``meta_crc32`` KEY itself makes the file look legacy (no CRC to
    verify) — only dropping legacy support would close it."""
    sharded_checkpoint(rng, tmp_path)
    path = os.path.join(str(tmp_path), "SHARDS")
    with open(path, "rb") as f:
        data = bytearray(f.read())
    data[data.index(b'"n_users"') + 2] |= 0x80    # invalid continuation
    with open(path, "wb") as f:
        f.write(bytes(data))
    eng = make_sharded(2)
    with pytest.raises(CorruptCheckpointError, match="not valid json"):
        eng.restore(str(tmp_path))


# ---------------------------------------------------------------------------
# Satellite 3: legacy ENGINE-file checkpoints through the sharded engine
# ---------------------------------------------------------------------------

def legacy_flat_checkpoint(rng, tmp_path):
    """A pre-fold flat checkpoint: separate ENGINE file, no CRC fields."""
    eng, store = make_engine()
    events = add_events(rng, 14)
    eng.submit(events)
    eng.run_until_drained()
    eng.checkpoint(str(tmp_path), 1)
    latest = os.path.join(str(tmp_path), "LATEST")
    with open(latest) as f:
        meta = json.load(f)
    legacy_log = meta.pop("engine")
    for k in ("meta_crc32", "npz_crc32", "npz_bytes"):
        meta.pop(k)
    with open(latest, "w") as f:
        json.dump(meta, f)
    with open(os.path.join(str(tmp_path), "ENGINE"), "w") as f:
        json.dump(legacy_log, f)
    prev = os.path.join(str(tmp_path), "LATEST.prev")
    if os.path.exists(prev):     # first commit has no retained pair
        os.remove(prev)
    return events, eng


def test_legacy_engine_file_restores_through_one_shard_router(rng,
                                                              tmp_path):
    events, eng1 = legacy_flat_checkpoint(rng, tmp_path)
    eng = make_sharded(1)
    eng.restore(str(tmp_path))
    assert eng.shards[0].watermark == len(events) - 1
    res = eng.submit(events)               # full replay: all duplicates
    assert res.deduped == len(events) and res.admitted == 0
    np.testing.assert_array_equal(
        np.asarray(eng.shards[0].store.state.materialized_user_vecs()),
        vecs(eng1.store))


def test_legacy_engine_file_reshards_into_two_shards(rng, tmp_path):
    """The 1→2 reshard path must pick up the legacy ENGINE log as a
    legacy log — a replay is fully deduped, never double-applied."""
    events, eng1 = legacy_flat_checkpoint(rng, tmp_path)
    eng = make_sharded(2)
    eng.restore(str(tmp_path))
    res = eng.submit(events)
    assert res.deduped == len(events) and res.admitted == 0
    got = np.empty((M, P.n_items), np.float32)
    for u in range(M):
        s, r = eng.spec.shard_of(u), eng.spec.local_row(u)
        got[u] = np.asarray(
            eng.shards[s].store.state.materialized_user_vecs()[r])
    np.testing.assert_array_equal(got, vecs(eng1.store))


# ---------------------------------------------------------------------------
# FaultPlan mechanics
# ---------------------------------------------------------------------------

def test_fault_plans_do_not_nest():
    with faults.inject(faults.FaultPlan()):
        with pytest.raises(RuntimeError, match="do not nest"):
            with faults.inject(faults.FaultPlan()):
                pass
    assert faults.active_plan() is None


def test_injected_crash_escapes_except_exception():
    plan = faults.FaultPlan(crash_site="LATEST.pre_replace")
    with faults.inject(plan):
        with pytest.raises(faults.InjectedCrash):
            try:
                faults.trip("LATEST.pre_replace")
            except Exception:      # a retry loop must NOT swallow this
                pytest.fail("InjectedCrash was caught by except Exception")
    assert plan.fired == ["LATEST.pre_replace"]


def test_crash_on_nth_hit_selects_the_shard():
    plan = faults.FaultPlan(crash_site="npz.post_replace", crash_on_hit=2)
    with faults.inject(plan):
        faults.trip("npz.post_replace")        # shard 0: survives
        with pytest.raises(faults.InjectedCrash):
            faults.trip("npz.post_replace")    # shard 1: dies
    assert plan.fired == ["npz.post_replace"] * 2


def test_redelivered_keeps_original_seqnos(rng):
    events = add_events(rng, 20)
    dups = faults.redelivered(events, seed=4, dup_frac=0.5)
    assert 0 < len(dups) < len(events)
    orig = {ev.seqno: ev for ev in events}
    for ev in dups:
        assert dataclasses.asdict(orig[ev.seqno]).keys() == \
            dataclasses.asdict(ev).keys()
        assert orig[ev.seqno].user == ev.user
