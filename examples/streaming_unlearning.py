"""Streaming unlearning at scale: the batched SPMD engine processes a
mixed stream of basket arrivals and GDPR deletion requests, survives a
simulated crash (exactly-once recovery), and serves recommendations from
the live state store.

    PYTHONPATH=src python examples/streaming_unlearning.py
"""
import dataclasses
import tempfile
import time

import numpy as np
import jax.numpy as jnp

from repro.core import knn
from repro.data import stream, synthetic
from repro.streaming import StateStore, StoreConfig, StreamingEngine

ds = synthetic.generate("instacart", scale=0.02, seed=0)
p = ds.params
n_users = len(ds.histories)
store = StateStore(StoreConfig(
    n_users=n_users, n_items=p.n_items,
    max_baskets=max(len(h) for h in ds.histories.values()) + 8,
    max_basket_size=max((len(b) for h in ds.histories.values()
                         for b in h), default=8) + 2))
engine = StreamingEngine(store, p, batch_size=256)

events = stream.make_stream(ds.histories, deletion_user_rate=5e-3,
                            deletion_basket_frac=0.1,
                            item_deletion_rate=2e-3, seed=1)
n_dels = sum(1 for e in events if e.kind != 1)
print(f"stream: {len(events)} events ({n_dels} deletion requests) "
      f"for {n_users} users")

# process half, then simulate a crash + recovery
engine.submit(events)
half = len(events) // (2 * engine.batch_size)
for _ in range(half):
    engine.step()
ckpt = tempfile.mkdtemp()
engine.checkpoint(ckpt, step=half)
print(f"processed {engine.metrics.events_processed} events, "
      f"checkpointed, simulating crash...")

store2 = StateStore(dataclasses.replace(store.cfg))
engine2 = StreamingEngine(store2, p, batch_size=256)
engine2.restore(ckpt)
# at-least-once redelivery of the WHOLE stream: duplicates are skipped
engine2.submit([dataclasses.replace(e, seqno=i)
                for i, e in enumerate(events)])
t0 = time.perf_counter()
n = engine2.run_until_drained()
dt = time.perf_counter() - t0
print(f"recovered + drained {n} remaining events in {dt:.2f}s "
      f"({n/max(dt,1e-9):,.0f} events/s); "
      f"stability refreshes: {engine2.metrics.refreshes}")

# serve from the live store
corpus = store2.state.materialized_user_vecs()
q = corpus[:256]
t0 = time.perf_counter()
pred = knn.predict(q, corpus, k=p.k_neighbors, alpha=p.alpha,
                   exclude_self=True, query_ids=jnp.arange(256))
recs = knn.recommend_topn(pred, 10)
recs.block_until_ready()
print(f"served 256 users from live state in "
      f"{(time.perf_counter()-t0)*1e3:.1f} ms")
print("user 0 top-10:", np.asarray(recs[0]))
