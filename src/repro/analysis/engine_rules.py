"""Rule family (c): engine invariants (EN01–EN03).

EN01: every public state-store/engine path that performs a raw durable
write must reach the single atomic LATEST commit site
(``atomic_write_json``) — a write path that bypasses it can leave a
torn manifest after a crash.  Background writer threads are held to the
same discipline regardless of visibility: a ``threading.Thread`` whose
``target`` transitively writes raw without reaching the commit sink is
flagged even from a private spawner, because the thread outlives its
caller and so escapes any public path's commit reasoning (the §12 async
checkpointer stays clean by construction — its worker runs opaque jobs,
and the jobs the engine submits END in the atomic replace).  EN02: fault-injection site names form a
closed registry — a ``trip("...")`` with an unregistered name silently
never fires, so the chaos suite stops covering that crash window.
EN03: ``BENCH_updates.json`` summary keys must follow the naming
convention ``repro.analysis.bench_schema`` classifies, or the trend
gate cannot tell a gated metric from an informational one.
"""
from __future__ import annotations

import ast
import json
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis import astutil
from repro.analysis.bench_schema import classify_summary_key
from repro.analysis.report import Finding

# The one function allowed to write the LATEST manifest; reaching it is
# what makes a durable-write path "committed".
COMMIT_SINK = "atomic_write_json"


def _f(rule: str, path: Path, line: int, msg: str) -> Finding:
    return Finding(rule=rule, path=str(path), line=line, message=msg)


def _is_public(qualname: str) -> bool:
    return not any(part.startswith("_") for part in qualname.split("."))


def _thread_target(node: ast.Call) -> Optional[ast.expr]:
    """The ``target=`` expression of a ``threading.Thread(...)`` call."""
    f = node.func
    name = f.attr if isinstance(f, ast.Attribute) else getattr(f, "id",
                                                               None)
    if name != "Thread":
        return None
    for kw in node.keywords:
        if kw.arg == "target":
            return kw.value
    return None


def _target_qualname(expr: ast.expr, cls: Optional[str],
                     funcs: Dict[str, "astutil.FuncInfo"]) -> Optional[str]:
    """Resolve a thread target to a module-local qualname, or None."""
    if isinstance(expr, ast.Name) and expr.id in funcs:
        return expr.id
    if (isinstance(expr, ast.Attribute) and cls is not None
            and isinstance(expr.value, ast.Name)
            and expr.value.id == "self"
            and f"{cls}.{expr.attr}" in funcs):
        return f"{cls}.{expr.attr}"
    return None


def _reaches_commit(reach: Set[str], funcs: Dict[str, "astutil.FuncInfo"],
                    commit_names: Set[str]) -> bool:
    """True when any function in ``reach`` hits the commit sink —
    as a module-local definition or an imported name."""
    if reach & commit_names:
        return True
    return any(COMMIT_SINK in astutil.referenced_names(funcs[q].node)
               for q in reach if q in funcs)


def check_commit_paths_in_tree(tree: ast.Module,
                               path: Path) -> List[Finding]:
    """EN01 over one parsed module."""
    findings: List[Finding] = []
    funcs = astutil.collect_functions(tree)
    edges = astutil.call_edges(funcs)
    writers = {q for q, info in funcs.items()
               if q != COMMIT_SINK and q.rsplit(".", 1)[-1] != COMMIT_SINK
               and astutil.writes_raw(info.node)}
    if not writers:
        return findings
    commit_names = {q for q in funcs
                    if q.rsplit(".", 1)[-1] == COMMIT_SINK}
    for q, info in sorted(funcs.items()):
        if not _is_public(q):
            continue
        reach = astutil.transitive_closure(q, edges)
        if not reach & writers:
            continue
        if not _reaches_commit(reach, funcs, commit_names):
            findings.append(_f(
                "EN01", path, info.node.lineno,
                f"public `{q}` reaches a raw durable write "
                f"({sorted(reach & writers)}) without reaching the "
                f"atomic commit site `{COMMIT_SINK}`"))
    # Thread targets: the spawned function escapes every synchronous
    # call path's commit reasoning, so it is held to the discipline
    # directly — private spawners included.
    for q, info in sorted(funcs.items()):
        for node in ast.walk(info.node):
            if not isinstance(node, ast.Call):
                continue
            target = _thread_target(node)
            if target is None:
                continue
            tq = _target_qualname(target, info.cls, funcs)
            if tq is None:
                continue
            reach = astutil.transitive_closure(tq, edges)
            if not reach & writers:
                continue
            if not _reaches_commit(reach, funcs, commit_names):
                findings.append(_f(
                    "EN01", path, node.lineno,
                    f"`{q}` spawns a thread on `{tq}`, which reaches a "
                    f"raw durable write ({sorted(reach & writers)}) "
                    f"without reaching the atomic commit site "
                    f"`{COMMIT_SINK}`"))
    return findings


def check_commit_paths(root: Path) -> List[Finding]:
    """EN01 over the streaming state-store, engine, and async-writer
    modules."""
    findings: List[Finding] = []
    for rel in ("streaming/state_store.py", "streaming/engine.py",
                "streaming/async_checkpoint.py"):
        path = root / "src" / "repro" / rel
        if path.exists():
            sf = astutil.load(path)
            findings += check_commit_paths_in_tree(sf.tree, path)
    return findings


def _resolve_tuple(expr: ast.expr,
                   env: Dict[str, ast.expr]) -> Tuple[str, ...]:
    """Evaluate a registry expression: string-tuple literals plus
    ``NAME + (...)`` concatenation."""
    if isinstance(expr, ast.Name) and expr.id in env:
        return _resolve_tuple(env[expr.id], env)
    if isinstance(expr, (ast.Tuple, ast.List)):
        out = []
        for e in expr.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.append(e.value)
        return tuple(out)
    if isinstance(expr, ast.BinOp) and isinstance(expr.op, ast.Add):
        return (_resolve_tuple(expr.left, env)
                + _resolve_tuple(expr.right, env))
    return ()


def registered_fault_sites(faults_path: Path) -> Set[str]:
    """The closed site registry parsed from ``faults.py`` (the union of
    every ``*_SITES`` module-level tuple)."""
    tree = astutil.load(faults_path).tree
    env: Dict[str, ast.expr] = {}
    for node in tree.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            env[node.targets[0].id] = node.value
    sites: Set[str] = set()
    for name, value in env.items():
        if name.endswith("_SITES"):
            sites |= set(_resolve_tuple(value, env))
    return sites


def _trip_arg(node: ast.Call) -> Optional[ast.expr]:
    f = node.func
    name = f.attr if isinstance(f, ast.Attribute) else \
        getattr(f, "id", None)
    if name == "trip" and node.args:
        return node.args[0]
    return None


def check_trip_calls_in_tree(tree: ast.Module, path: Path,
                             sites: Set[str]) -> Tuple[List[Finding],
                                                       Set[str]]:
    """EN02 over one module's ``trip(...)`` calls.

    Returns (findings, covered-sites).  Literal args must be registered;
    f-string args must end in a constant suffix matching a registered
    site's tail (e.g. ``f"SHARDS.{tag}.pre_replace"`` never occurs —
    the sharded sites use fixed names — but ``f"{prefix}.pre_replace"``
    would cover every site ending in ``.pre_replace``).
    """
    findings: List[Finding] = []
    covered: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        arg = _trip_arg(node)
        if arg is None:
            continue
        if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
            if arg.value in sites:
                covered.add(arg.value)
            else:
                findings.append(_f(
                    "EN02", path, node.lineno,
                    f"trip({arg.value!r}) names an unregistered fault "
                    "site"))
        elif isinstance(arg, ast.JoinedStr):
            suffix = ""
            if arg.values and isinstance(arg.values[-1], ast.Constant):
                suffix = str(arg.values[-1].value)
            matched = {s for s in sites if suffix and s.endswith(suffix)}
            if matched:
                covered |= matched
            else:
                findings.append(_f(
                    "EN02", path, node.lineno,
                    f"dynamic trip(f\"...{suffix}\") matches no "
                    "registered fault site"))
        else:
            findings.append(_f(
                "EN02", path, node.lineno,
                "trip() argument is not a statically-checkable string"))
    return findings, covered


def check_fault_registry(root: Path) -> List[Finding]:
    """EN02: all trip sites registered AND all registered sites tripped."""
    faults_path = root / "src" / "repro" / "streaming" / "faults.py"
    sites = registered_fault_sites(faults_path)
    findings: List[Finding] = []
    covered: Set[str] = set()
    sdir = root / "src" / "repro" / "streaming"
    for path in sorted(sdir.glob("*.py")):
        if path.name == "faults.py":
            continue
        f, c = check_trip_calls_in_tree(astutil.load(path).tree, path,
                                        sites)
        findings += f
        covered |= c
    for site in sorted(sites - covered):
        findings.append(_f(
            "EN02", faults_path, 1,
            f"registered fault site {site!r} is never tripped by any "
            "streaming write path"))
    return findings


def check_bench_keys(json_path: Path) -> List[Finding]:
    """EN03 over every run summary in a BENCH json file."""
    findings: List[Finding] = []
    if not json_path.exists():
        return findings
    try:
        data = json.loads(json_path.read_text())
    except (ValueError, OSError) as e:
        return [_f("EN03", json_path, 1, f"unreadable bench json: {e}")]
    for i, run in enumerate(data.get("runs", [])):
        for key in run.get("summary", {}):
            if classify_summary_key(key) == "unknown":
                findings.append(_f(
                    "EN03", json_path, 1,
                    f"runs[{i}] ({run.get('bench', '?')}): summary key "
                    f"{key!r} does not follow the gated/parity naming "
                    "convention"))
    return findings
