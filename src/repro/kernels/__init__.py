"""Pallas TPU kernels (+ pure-jnp oracles and jit dispatchers).

knn_topk           — fused similarity × streaming top-k (TIFU serving,
                     retrieval_cand cells)
decayed_scatter    — one-hot-matmul weighted multi-hot scatter (TIFU
                     user vectors; EmbeddingBag substrate)
sparse_row_scatter — sparse per-row scatter-add into the [M, I] state
                     (batched add/delete-path deltas, DESIGN.md §3.3/§3.5)
sparse_row_gather  — sparse per-row gather of the [M, I] state (the read
                     half of the pair: update-path supports)
tile_plan          — host/jit touched-tile plans driving the sparse pair's
                     block index maps (O(U·W) TPU HBM traffic)
flash_attention    — blocked online-softmax attention (LM train/prefill)
"""
from repro.kernels import ops, ref, tile_plan
from repro.kernels.ops import (default_impl, flash_attention, knn_topk,
                               multihot_scatter, sparse_row_gather,
                               sparse_row_scatter)

__all__ = ["ops", "ref", "tile_plan", "default_impl", "flash_attention",
           "knn_topk", "multihot_scatter", "sparse_row_gather",
           "sparse_row_scatter"]
