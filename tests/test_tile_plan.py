"""Tile-planned sparse kernels (kernels.tile_plan + the scatter/gather
pair) vs the ref.py oracles — interpret-mode equivalence sweep plus the
plan-level DMA accounting that pins O(U·W) TPU traffic (ISSUE 3):
dirty-tile DMAs per row must equal the touched-tile count, never I/bi."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref, tile_plan
from repro.kernels.sparse_row_gather import sparse_row_gather
from repro.kernels.sparse_row_scatter import sparse_row_scatter


def _touched(rows, ids, bi):
    """{(row, tile)} pairs a batch genuinely dirties (numpy oracle)."""
    rows, ids = np.asarray(rows), np.asarray(ids)
    return {(int(r), int(i) // bi)
            for r, row_ids in zip(rows, ids) for i in row_ids if i >= 0}


def _make(rng, m, items, u, w, mode):
    """(table, rows, ids, vals) with ids clustered / spread / mixed."""
    table = jnp.asarray(rng.normal(size=(m, items)), jnp.float32)
    rows = jnp.asarray(rng.integers(0, m, u), jnp.int32)
    if mode == "clustered":          # every row's ids inside ONE tile
        base = rng.integers(0, items // 128, u) * 128
        ids = base[:, None] + rng.integers(0, min(128, items), (u, w))
        ids = np.minimum(ids, items - 1)
    elif mode == "spread":           # ids across many tiles
        ids = rng.choice(items, size=(u, w), replace=False) \
            if items >= u * w else rng.integers(0, items, (u, w))
    else:                            # mixed + PADs
        ids = rng.integers(-1, items, (u, w))
    ids = jnp.asarray(ids, jnp.int32)
    vals = jnp.asarray(rng.normal(size=(u, w)), jnp.float32)
    return table, rows, ids, vals


@pytest.mark.parametrize("m,items,u,w,bi", [
    (32, 1024, 8, 16, 128),
    (64, 2048, 16, 24, 512),
    (16, 640, 8, 8, 128),            # non-pow2 items
])
@pytest.mark.parametrize("mode", ["clustered", "spread", "mixed"])
def test_tile_planned_pair_matches_ref(rng, m, items, u, w, bi, mode):
    table, rows, ids, vals = _make(rng, m, items, u, w, mode)
    out = sparse_row_scatter(table, rows, ids, vals, bi=bi, interpret=True)
    exp = ref.sparse_row_scatter_ref(table, rows, ids, vals)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-4)
    got = sparse_row_gather(table, rows, ids, bi=bi, interpret=True)
    # reads are exact: every output element is a single table read
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.sparse_row_gather_ref(table, rows,
                                                              ids)))


def test_scatter_unique_support_is_bitwise_exact(rng):
    """With a duplicate-free support each cell receives exactly one add:
    the tile-planned kernel must be bit-for-bit equal to the oracle."""
    m, items, u, w, bi = 16, 1024, 6, 12, 128
    table = jnp.asarray(rng.normal(size=(m, items)), jnp.float32)
    rows = jnp.asarray(rng.permutation(m)[:u], jnp.int32)   # distinct rows
    ids = jnp.asarray(rng.choice(items, size=(u, w), replace=False),
                      jnp.int32)
    vals = jnp.asarray(rng.normal(size=(u, w)), jnp.float32)
    out = sparse_row_scatter(table, rows, ids, vals, bi=bi, interpret=True)
    exp = ref.sparse_row_scatter_ref(table, rows, ids, vals)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_duplicate_rows_with_differing_supports(rng):
    """THE case the (row, tile) work-item sort exists for: duplicate
    target rows whose ids touch different tile sets.  A rectangular
    per-row plan would revisit a block non-consecutively (undefined);
    the sorted plan accumulates every visit in one run."""
    m, items, bi = 8, 1024, 128
    table = jnp.asarray(rng.normal(size=(m, items)), jnp.float32)
    rows = jnp.asarray([5, 5, 5, 2], jnp.int32)
    ids = jnp.asarray([[0, 1, 2, 900],        # tiles {0, 7}
                       [130, 131, -1, 901],   # tiles {1, 7}
                       [0, 300, 640, -1],     # tiles {0, 2, 5}
                       [5, 6, 7, 8]], jnp.int32)
    vals = jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)
    out = sparse_row_scatter(table, rows, ids, vals, bi=bi, interpret=True)
    exp = ref.sparse_row_scatter_ref(table, rows, ids, vals)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5)
    got = sparse_row_gather(table, rows, ids, bi=bi, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(got),
        np.asarray(ref.sparse_row_gather_ref(table, rows, ids)))


def test_all_pad_rows_and_all_pad_batch(rng):
    m, items, bi = 8, 512, 128
    table = jnp.asarray(rng.normal(size=(m, items)), jnp.float32)
    # some rows all-PAD, some real
    rows = jnp.asarray([1, 3, 6], jnp.int32)
    ids = jnp.asarray([[4, 200, -1, -1],
                       [-1, -1, -1, -1],
                       [500, -1, 3, -1]], jnp.int32)
    vals = jnp.asarray(rng.normal(size=(3, 4)), jnp.float32)
    out = sparse_row_scatter(table, rows, ids, vals, bi=bi, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(ref.sparse_row_scatter_ref(table, rows, ids, vals)),
        atol=1e-5)
    got = sparse_row_gather(table, rows, ids, bi=bi, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(got),
        np.asarray(ref.sparse_row_gather_ref(table, rows, ids)))
    # an ENTIRELY pad batch is an identity scatter / zero gather
    ids0 = jnp.full((3, 4), -1, jnp.int32)
    out0 = sparse_row_scatter(table, rows, ids0, vals, bi=bi,
                              interpret=True)
    np.testing.assert_array_equal(np.asarray(out0), np.asarray(table))
    got0 = sparse_row_gather(table, rows, ids0, bi=bi, interpret=True)
    np.testing.assert_array_equal(np.asarray(got0), np.zeros((3, 4)))


# ---------------------------------------------------------------------------
# Plan-level properties: DMA accounting + the replay oracles
# ---------------------------------------------------------------------------

def test_plan_dma_tiles_equal_touched_not_all_tiles(rng):
    """Acceptance: DMA'd table tiles == touched tiles, NOT U · I/bi.
    (Padding steps clone the previous block, which the pipeline does not
    re-fetch, so block-index changes count the real DMAs.)"""
    m, items, u, w, bi = 64, 4096, 12, 16, 128     # 32 tiles/row dense
    table_tiles = items // bi
    rows = np.concatenate([rng.integers(0, m, u - 2), [7, 7]])  # dup rows
    ids = rng.integers(-1, items, (u, w))
    plan = tile_plan.build_plan(jnp.asarray(rows, jnp.int32),
                                jnp.asarray(ids, jnp.int32),
                                bi=bi, t_max=min(w, table_tiles),
                                order="target")
    touched = _touched(rows, ids, bi)
    assert tile_plan.plan_dma_tiles(plan) == len(touched)
    assert len(touched) < u * table_tiles          # clean tiles skipped
    # gather plan: per-batch-row touched tiles (duplicates read twice);
    # consecutive rows can share a boundary tile, hence <=
    plan_g = tile_plan.build_plan(jnp.asarray(rows, jnp.int32),
                                  jnp.asarray(ids, jnp.int32),
                                  bi=bi, t_max=min(w, table_tiles),
                                  order="batch")
    per_row = sum(len({int(i) // bi for i in row if i >= 0})
                  for row in ids)
    assert 0 < tile_plan.plan_dma_tiles(plan_g) <= per_row
    assert per_row < u * table_tiles


def test_clustered_batch_dmas_one_tile_per_row(rng):
    """Ids clustered in one tile: exactly one DMA per distinct row for
    the scatter plan — the flat latency-vs-vocabulary regime."""
    m, items, u, w, bi = 32, 8192, 8, 16, 512
    rows = rng.permutation(m)[:u]
    ids = 1024 + rng.integers(0, 512, (u, w))      # all inside tile 2
    plan = tile_plan.build_plan(jnp.asarray(rows, jnp.int32),
                                jnp.asarray(ids, jnp.int32),
                                bi=bi, t_max=4, order="target")
    assert tile_plan.plan_dma_tiles(plan) == u     # == touched, != I/bi
    assert items // bi == 16


def test_replay_oracles_match_refs(rng):
    """The ref.py plan-consistency oracle (which asserts the
    consecutive-revisit contract while replaying) reproduces the plain
    oracles on both plan orders."""
    m, items, u, w, bi = 16, 1024, 10, 12, 128
    t_max = min(w, items // bi)
    table = jnp.asarray(rng.normal(size=(m, items)), jnp.float32)
    rows = jnp.asarray(rng.integers(0, m, u), jnp.int32)   # dups likely
    ids = jnp.asarray(rng.integers(-1, items, (u, w)), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(u, w)), jnp.float32)

    order = jnp.argsort(rows, stable=True)
    rows_s, ids_s = rows[order], ids[order]
    vals_s = jnp.where(ids_s >= 0, vals[order], 0.0)
    plan = tile_plan.build_plan(rows_s, ids_s, bi=bi, t_max=t_max,
                                order="target")
    got = ref.replay_scatter_plan_ref(table, ids_s, vals_s, plan, bi)
    np.testing.assert_allclose(
        got, np.asarray(ref.sparse_row_scatter_ref(table, rows, ids,
                                                   vals)), atol=1e-5)

    plan_g = tile_plan.build_plan(rows, ids, bi=bi, t_max=t_max,
                                  order="batch")
    got_g = ref.replay_gather_plan_ref(table, ids, plan_g, bi)
    np.testing.assert_array_equal(
        got_g, np.asarray(ref.sparse_row_gather_ref(table, rows, ids)))


def test_ops_dispatch_fallback_and_t_max_selection(rng):
    """n_items % 128 != 0 falls back to the XLA reference; concrete
    batches get a measured (pow2) T_max below the static bound."""
    table = jnp.asarray(rng.normal(size=(8, 130)), jnp.float32)  # 130 % 128
    rows = jnp.asarray([1, 2], jnp.int32)
    ids = jnp.asarray(rng.integers(-1, 130, (2, 6)), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(2, 6)), jnp.float32)
    assert ops._plan_dims(130, ids) is None
    out = ops.sparse_row_scatter(table, rows, ids, vals, impl="interpret")
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(ref.sparse_row_scatter_ref(table, rows, ids, vals)),
        atol=1e-5)
    got = ops.sparse_row_gather(table, rows, ids, impl="interpret")
    np.testing.assert_array_equal(
        np.asarray(got),
        np.asarray(ref.sparse_row_gather_ref(table, rows, ids)))

    # concrete ids -> measured t_max; tracers -> static worst case
    ids_c = jnp.asarray([[0, 1, 2, 3], [128, 129, 130, 131]], jnp.int32)
    bi, t_max = ops._plan_dims(1024, ids_c)
    assert bi == 512 and t_max == 1                # one tile per row
    ids_sp = jnp.asarray([[0, 600, 1023, -1]], jnp.int32)
    assert ops._plan_dims(1024, ids_sp) == (512, 2)
    traced = jax.eval_shape(lambda i: jnp.asarray(
        ops._plan_dims(1024, i)[1]), ids_c)
    assert traced.shape == ()                      # static bound path runs


def test_engine_mixed_stream_tile_planned_matches_ref_and_xla():
    """>= 500 interleaved add/delete events through the FULL engine with
    every sparse kernel routed through the tile-planned Pallas pair in
    interpret mode (ops.default_impl): the final state must match both
    the XLA-reference arm of the same stream and the paper-faithful
    RefEngine (ISSUE 3 acceptance)."""
    from repro.core import RefEngine, TifuParams
    from repro.streaming import Event, StateStore, StoreConfig, \
        StreamingEngine
    from repro.core.types import (KIND_ADD_BASKET, KIND_DEL_BASKET,
                                  KIND_DEL_ITEM)

    p = TifuParams(n_items=640, group_size=3, r_b=0.9, r_g=0.7)  # 5 tiles
    m, n, b, k = 16, 12, 4, 12
    rng = np.random.default_rng(3)
    ref_eng = RefEngine(p, dtype=np.float32)
    events = []
    for _ in range(520):
        u = int(rng.integers(0, m))
        st = ref_eng.state(u)
        nb = st.n_baskets
        if nb == 0 or (rng.random() < 0.6 and nb < n - 2):
            items = rng.choice(p.n_items, size=int(rng.integers(1, b)),
                               replace=False).astype(np.int32)
            ref_eng.add_basket(u, items)
            events.append(Event(KIND_ADD_BASKET, u, items=items))
        elif rng.random() < 0.5:
            pos = int(rng.integers(0, nb))
            ref_eng.delete_basket(u, pos)
            events.append(Event(KIND_DEL_BASKET, u, pos=pos))
        else:
            pos = int(rng.integers(0, nb))
            item = int(rng.choice(ref_eng.state(u).history[pos]))
            ref_eng.delete_item(u, pos, item)
            events.append(Event(KIND_DEL_ITEM, u, pos=pos, item=item))

    def run(impl):
        with ops.default_impl(impl):
            store = StateStore(StoreConfig(n_users=m, n_items=p.n_items,
                                           max_baskets=n, max_basket_size=b,
                                           max_groups=k))
            eng = StreamingEngine(store, p, batch_size=16)
            eng.submit(list(events))
            assert eng.run_until_drained() == len(events)
            return np.asarray(store.state.materialized_user_vecs())

    xla = run("ref")                      # the XLA reference arm
    planned = run("interpret")            # tile-planned Pallas pair
    np.testing.assert_allclose(planned, xla, rtol=1e-4, atol=1e-5)
    for u in range(m):
        np.testing.assert_allclose(
            planned[u], ref_eng.state(u).user_vec.astype(np.float32),
            rtol=1e-4, atol=1e-5, err_msg=f"u={u}")


def test_ops_interpret_matches_ref_end_to_end(rng):
    """ops-level dispatch: impl='interpret' (tile-planned Pallas) equals
    impl='ref' for a divisible vocabulary."""
    table = jnp.asarray(rng.normal(size=(16, 1024)), jnp.float32)
    rows = jnp.asarray(rng.integers(0, 16, 6), jnp.int32)
    ids = jnp.asarray(rng.integers(-1, 1024, (6, 10)), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(6, 10)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.sparse_row_scatter(table, rows, ids, vals,
                                          impl="interpret")),
        np.asarray(ops.sparse_row_scatter(table, rows, ids, vals,
                                          impl="ref")), atol=1e-5)
    np.testing.assert_array_equal(
        np.asarray(ops.sparse_row_gather(table, rows, ids,
                                         impl="interpret")),
        np.asarray(ops.sparse_row_gather(table, rows, ids, impl="ref")))
