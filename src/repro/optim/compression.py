"""Gradient compression for the inter-pod (DCI) axis (DESIGN.md §5).

At 512+ chips the gradient all-reduce crosses the data-center
interconnect once per step; int8 quantization with error feedback
(1-bit-Adam-style memory, Seide et al. 2014 / Tang et al. 2021) cuts
those bytes 4× vs f32 / 2× vs bf16 while keeping SGD convergence
(the quantization error is fed back into the next step, so the
compressed stream is unbiased over time).

Usage (shard_map DP training or a custom grad sync):

    state = init_error_feedback(grads)
    def sync(g, state):
        q, scales, state = compress_with_feedback(g, state)
        q = jax.lax.psum(q, "pod")          # int8 wire traffic
        return decompress(q, scales, n_pods), state

Hierarchical reduction helper included: reduce-scatter intra-pod in
bf16, all-reduce inter-pod in int8, all-gather intra-pod.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat


def quantize_int8(x):
    """Per-tensor symmetric int8. Returns (q: int8, scale: f32)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale, dtype=jnp.float32):
    """Inverse of :func:`quantize_int8`: q·scale, cast to ``dtype``.

    Round-trip error is ≤ scale/2 per element (symmetric rounding).
    """
    return (q.astype(jnp.float32) * scale).astype(dtype)


def quantize_int8_rows(x):
    """Per-ROW symmetric int8 for the quantized serving corpus (§8.4).

    x: f32[M, I] → (q: int8[M, I], scale: f32[M])
    with ``scale[r]`` the POWER OF TWO ≥ ``max(|x[r]|, 1e-30)/127``
    (next-above, or equal when already a power of two) and
    ``q[r] = round(x[r]/scale[r])`` clipped to ±127.

    Power-of-two scales are the load-bearing choice: every scale
    application in the serving kernels (s_q·s_c, s_c², ×acc) is then an
    exact f32 exponent shift, so each blended score involves exactly
    one rounding — which makes the int8 scores invariant to FMA
    contraction (``a·b − c`` fuses to ``fma(a, b, −c)`` or not,
    depending on how XLA/Mosaic lowers each program; with a·b exact
    both round identically).  That is what upgrades the D-tiled int8
    path's kernel-vs-oracle agreement from allclose to bitwise.  The
    cost is ≤ 1 bit of the 8: per-element round-trip error is ≤
    scale[r]/2 ≤ max|x[r]|/127 (vs /254 for a free scale) — pinned by
    tests/test_quantized_serving.py.

    Row-wise scaling also makes the representation corpus-partition
    invariant: a row quantizes to the same (q, scale) on any shard or
    slice, so sharded int8 serving scores are bitwise the single-corpus
    ones, and a streaming-update row refresh re-quantizes exactly the
    touched rows (`streaming.state_store.StateStore.quantized_corpus`).
    """
    xf = x.astype(jnp.float32)
    raw = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1), 1e-30) / 127.0
    # next power of two ≥ raw: frexp gives raw = m·2^e with m ∈ [0.5, 1);
    # m == 0.5 means raw IS 2^(e−1), else round up to 2^e.  The pow2 is
    # assembled from its IEEE-754 exponent bits — XLA's exp2() is an
    # APPROXIMATION (exp2(15) → 32767.984 on CPU) and would silently
    # void the exactness invariant above.
    mant, exp = jnp.frexp(raw)
    e = jnp.where(mant == 0.5, exp - 1, exp).astype(jnp.int32)
    scale = jax.lax.bitcast_convert_type((e + 127) << 23, jnp.float32)
    q = jnp.clip(jnp.round(xf / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8_rows(q, scale, dtype=jnp.float32):
    """Inverse of :func:`quantize_int8_rows`: ``q · scale[:, None]``.

    q: int8[..., M, I] with a matching ``scale`` broadcast over the last
    axis (scale: f32[..., M]).  Exact elementwise f32 multiply — the
    serving kernels apply the same product in VMEM, so host-side
    dequantization reproduces the kernel's operand values bitwise.
    """
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def init_error_feedback(grads):
    """Residual accumulator pytree (same shapes as grads, f32)."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_with_feedback(grads, err):
    """Quantize (grad + residual); the rounding error is the new residual.

    Over steps the transmitted sum is exact (error feedback).
    Returns (q_tree int8, scale_tree f32 scalars, new_err_tree).
    """
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, scale = quantize_int8(target)
        decoded = dequantize_int8(q, scale)
        return q, scale, target - decoded

    out = jax.tree.map(one, grads, err)
    q = jax.tree.map(lambda t: t[0], out,
                     is_leaf=lambda x: isinstance(x, tuple))
    scales = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[2], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return q, scales, new_err


def decompress(q, scales, dtype=jnp.float32):
    """Dequantize a compressed gradient pytree leaf-by-leaf."""
    return jax.tree.map(lambda a, s: dequantize_int8(a, s, dtype), q,
                        scales)


def hierarchical_psum_mean(x, intra_axes, inter_axis, err=None):
    """Hierarchical gradient mean for shard_map DP training:

      reduce-scatter intra-pod (bf16 wire) → int8 all-reduce inter-pod
      (with optional error feedback) → all-gather intra-pod.

    Crossing the DCI with int8 moves 4× fewer bytes than f32.  Must run
    inside shard_map with ``intra_axes``/``inter_axis`` mesh axes.
    Returns (mean, new_err).
    """
    n_intra = 1
    for a in (intra_axes if isinstance(intra_axes, (tuple, list))
              else (intra_axes,)):
        n_intra *= compat.axis_size(a)
    n_inter = compat.axis_size(inter_axis)
    # intra-pod reduce-scatter over the flattened leading dim when
    # divisible; otherwise a plain psum (small tensors)
    flat = x.reshape(-1)
    if flat.shape[0] % n_intra == 0:
        part = jax.lax.psum_scatter(flat, intra_axes, scatter_dimension=0,
                                    tiled=True)
    else:
        part = jax.lax.psum(flat, intra_axes)
    if err is not None:
        q, scale, err = compress_with_feedback(part, err)
        q32 = jax.lax.psum(q.astype(jnp.int32), inter_axis)
        part = (q32.astype(jnp.float32) * scale)
    else:
        part = jax.lax.psum(part.astype(jnp.bfloat16), inter_axis)
        part = part.astype(jnp.float32)
    if flat.shape[0] % n_intra == 0:
        full = jax.lax.all_gather(part, intra_axes, axis=0, tiled=True)
    else:
        full = part
    return (full.reshape(x.shape) / (n_intra * n_inter)).astype(x.dtype), \
        err
