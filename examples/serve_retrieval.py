"""Batched retrieval serving: two-tower model + the knn_topk kernel
schedule — 1 query against 200k candidates without materializing the
score matrix.

    PYTHONPATH=src python examples/serve_retrieval.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.knn import streaming_topk
from repro.kernels.knn_topk import knn_topk
from repro.models import two_tower

c = two_tower.TwoTowerConfig(n_users=10_000, n_items=50_000,
                             n_item_cats=100, hist_len=16, embed_dim=64,
                             tower_mlp=(128, 64))
params = two_tower.init_params(c, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)

# precompute candidate-item embeddings (the offline index build)
n_cand = 200_000
item_batch = {"item_id": jnp.asarray(rng.integers(0, c.n_items, n_cand)),
              "item_cat": jnp.asarray(rng.integers(0, 100, n_cand))}
t0 = time.perf_counter()
cand = two_tower.item_tower(params, item_batch, c)
cand.block_until_ready()
print(f"indexed {n_cand:,} candidates in {time.perf_counter()-t0:.2f}s")

# online: a batch of user queries
users = {"user_id": jnp.asarray(rng.integers(0, c.n_users, 64)),
         "history": jnp.asarray(rng.integers(-1, c.n_items, (64, 16)),
                                jnp.int32)}
q = two_tower.user_tower(params, users, c)

# streaming top-k (the knn_topk schedule, portable path)
t0 = time.perf_counter()
vals, idx = streaming_topk(q, cand, k=100, metric="dot", chunk=25_000)
idx.block_until_ready()
print(f"streaming top-100 of 64 queries × {n_cand:,} candidates: "
      f"{(time.perf_counter()-t0)*1e3:.1f} ms")

# the Pallas kernel (interpret mode on CPU; compiled on TPU)
v2, i2 = knn_topk(q, cand, k=100, bq=64, bm=1000, metric="dot",
                  interpret=True)
agree = np.mean([len(set(map(int, a)) & set(map(int, b))) / 100
                 for a, b in zip(np.asarray(idx), np.asarray(i2))])
print(f"pallas kernel agreement with reference: {agree:.1%}")
print("query 0 top-5 candidates:", np.asarray(idx[0, :5]),
      "scores", np.round(np.asarray(vals[0, :5]), 3))
