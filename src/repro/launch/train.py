"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --smoke --steps 20            # reduced config, CPU
    PYTHONPATH=src python -m repro.launch.train --arch lm100m \
        --steps 300 --batch 2 --seq 256 --ckpt /tmp/lm100m

Production runs use the same ``train_step`` the dry-run lowers; this
driver adds the data pipeline, checkpoint/restart (resume from the
latest checkpoint automatically — fault tolerance), and async
checkpointing.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (AsyncCheckpointer, latest_step, restore_pytree)
from repro.models import transformer as T
from repro.optim import adamw


def lm100m_config():
    """~100M-parameter LM (deliverable (b): end-to-end training driver)."""
    return T.TransformerConfig(
        name="lm100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_head=64, d_ff=2048, vocab_size=32768, tie_embeddings=True,
        q_block=128, dtype=jnp.float32)


def synthetic_lm_batch(rng, batch, seq, vocab):
    """Markov-ish synthetic token stream (learnable structure)."""
    toks = rng.integers(0, vocab, (batch, seq + 1))
    # inject copy structure so loss visibly decreases
    toks[:, 2::2] = toks[:, 1:-1:2]
    return {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", type=str, default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.arch == "lm100m":
        c = lm100m_config()
    else:
        from repro.configs import get_arch
        arch = get_arch(args.arch)
        c = arch.make_smoke() if args.smoke else None
        if c is None:
            raise SystemExit("full assigned configs train via the dry-run "
                             "mesh; use --smoke on CPU")
    print(f"arch={c.name} params≈{c.n_params()/1e6:.1f}M")

    opt = adamw(lr=args.lr, warmup_steps=20, total_steps=args.steps)
    key = jax.random.PRNGKey(0)
    params = T.init_params(c, key)
    opt_state = opt.init(params)
    step0 = 0
    ckptr = None
    if args.ckpt:
        ckptr = AsyncCheckpointer(args.ckpt)
        if latest_step(args.ckpt) is not None:
            state = restore_pytree({"p": params, "o": opt_state,
                                    "step": jnp.zeros((), jnp.int32)},
                                   args.ckpt)
            params, opt_state = state["p"], state["o"]
            step0 = int(state["step"])
            print(f"resumed from step {step0}")

    train_step = jax.jit(T.make_train_step(c, opt), donate_argnums=(0, 1))
    rng = np.random.default_rng(1234)
    t_hist = []
    for step in range(step0, args.steps):
        batch = synthetic_lm_batch(rng, args.batch, args.seq, c.vocab_size)
        t0 = time.perf_counter()
        params, opt_state, metrics = train_step(params, opt_state, batch)
        loss = float(metrics["loss"])
        t_hist.append(time.perf_counter() - t0)
        if step % args.log_every == 0 or step == args.steps - 1:
            tok_s = args.batch * args.seq / np.mean(t_hist[-10:])
            print(f"step {step:5d} loss {loss:.4f} "
                  f"({np.mean(t_hist[-10:])*1e3:.0f} ms/step, "
                  f"{tok_s:,.0f} tok/s)", flush=True)
        if ckptr and step and step % args.ckpt_every == 0:
            ckptr.save({"p": params, "o": opt_state,
                        "step": jnp.asarray(step + 1, jnp.int32)}, step)
    if ckptr:
        ckptr.save({"p": params, "o": opt_state,
                    "step": jnp.asarray(args.steps, jnp.int32)},
                   args.steps)
        ckptr.close()
    print("final loss:", loss)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
