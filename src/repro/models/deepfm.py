"""DeepFM (Guo et al., arXiv:1703.04247).

39 categorical fields, embed_dim 10; FM interaction via the
sum-square/square-sum identity + deep MLP 400-400-400 on the
concatenated field embeddings; logits summed.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.common import apply_mlp, bce_with_logits, init_mlp, mlp_shapes
from repro.models.embedding import (TableSpec, embedding_lookup, flat_ids,
                                    init_table)

# Criteo-Kaggle style field cardinalities for 39 fields (13 bucketised
# numeric + 26 categorical, hashed) — public DeepFM experimental setup.
DEEPFM_VOCABS = tuple([64] * 13 + [
    1460, 583, 10131227, 2202608, 305, 24, 12517, 633, 3, 93145, 5683,
    8351593, 3194, 27, 14992, 5461306, 10, 5652, 2173, 4, 7046547, 18, 15,
    286181, 105, 142572])


@dataclasses.dataclass(frozen=True)
class DeepFMConfig:
    name: str = "deepfm"
    vocab_sizes: tuple = DEEPFM_VOCABS
    embed_dim: int = 10
    mlp: tuple = (400, 400, 400)
    dtype: Optional[object] = jnp.float32

    @property
    def n_fields(self):
        return len(self.vocab_sizes)

    @property
    def table(self) -> TableSpec:
        return TableSpec(self.vocab_sizes, self.embed_dim)

    def n_params(self) -> int:
        n = self.table.padded_rows() * (self.embed_dim + 1)
        dims = [self.n_fields * self.embed_dim, *self.mlp, 1]
        n += sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))
        return n


def init_params(c: DeepFMConfig, key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "table": init_table(k1, c.table, c.dtype),
        "linear": (jax.random.normal(k2, (c.table.padded_rows(),),
                                     jnp.float32) * 0.01).astype(c.dtype),
        "deep": init_mlp(k3, [c.n_fields * c.embed_dim, *c.mlp, 1], c.dtype),
        "bias": jnp.zeros((), c.dtype),
    }


def abstract_params(c: DeepFMConfig):
    shapes = {
        "table": (c.table.padded_rows(), c.embed_dim),
        "linear": (c.table.padded_rows(),),
        "deep": mlp_shapes([c.n_fields * c.embed_dim, *c.mlp, 1]),
        "bias": (),
    }
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s, c.dtype), shapes,
                        is_leaf=lambda x: isinstance(x, tuple))


def param_pspecs(c: DeepFMConfig, mesh, rules):
    n_dev = int(np.prod(mesh.devices.shape))
    rows = tuple(mesh.axis_names) if c.table.padded_rows() % n_dev == 0 \
        else (rules.tensor if rules.tensor in mesh.axis_names else None)
    deep = [{k: P(*([None] * len(s))) for k, s in l.items()}
            for l in mlp_shapes([c.n_fields * c.embed_dim, *c.mlp, 1])]
    return {"table": P(rows, None), "linear": P(rows), "deep": deep,
            "bias": P()}


def forward(params, batch, c: DeepFMConfig, mesh=None, rules=None):
    """batch: {"sparse": i32[B,39]} → logits [B]."""
    ids = batch["sparse"]
    emb = embedding_lookup(params["table"], ids, c.table)      # [B,F,K]
    from repro.models.dlrm import _constrain_batchwise
    emb = _constrain_batchwise(emb, mesh, rules, ids.shape[0])
    # FM 2nd order: 0.5 * ((Σ v)² − Σ v²) summed over K
    s = jnp.sum(emb, axis=1)
    fm2 = 0.5 * jnp.sum(jnp.square(s) - jnp.sum(jnp.square(emb), axis=1),
                        axis=-1)
    # FM 1st order
    fm1 = jnp.sum(jnp.take(params["linear"], flat_ids(ids, c.table)), axis=1)
    deep = apply_mlp(params["deep"],
                     emb.reshape(ids.shape[0], -1))[..., 0]
    return fm1 + fm2 + deep + params["bias"]


def loss_fn(params, batch, c: DeepFMConfig, mesh=None, rules=None):
    return bce_with_logits(forward(params, batch, c, mesh, rules),
                           batch["labels"])


def make_train_step(c: DeepFMConfig, optimizer, mesh=None, rules=None):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, c, mesh, rules))(params)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss}
    return train_step


def serve_step(params, batch, c: DeepFMConfig, mesh=None, rules=None):
    return jax.nn.sigmoid(forward(params, batch, c, mesh, rules))
