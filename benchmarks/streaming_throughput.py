"""Beyond-paper: batched SPMD streaming-engine throughput (events/s) vs
the paper's one-update-at-a-time Spark latencies, plus per-event latency
of the jit'd micro-batch across batch sizes."""
from __future__ import annotations

import time

from repro.data import stream, synthetic
from repro.streaming import StateStore, StoreConfig, StreamingEngine


def run(batch_size: int, n_events: int = 4096, scale=0.01):
    ds = synthetic.generate("tafeng", scale=scale, seed=0)
    p = ds.params
    n_users = len(ds.histories)
    store = StateStore(StoreConfig(
        n_users=n_users, n_items=p.n_items,
        max_baskets=max(len(h) for h in ds.histories.values()) + 8,
        max_basket_size=max((len(b) for h in ds.histories.values()
                             for b in h), default=8) + 2))
    eng = StreamingEngine(store, p, batch_size=batch_size)
    events = stream.make_stream(ds.histories, deletion_user_rate=0.02,
                                seed=1)[:n_events]
    eng.submit(events)
    eng.step()   # warm up / compile
    t0 = time.perf_counter()
    n = eng.run_until_drained()
    dt = time.perf_counter() - t0
    return n, dt, eng.metrics.batches


def main():
    print("batch_size,events,seconds,events_per_s,us_per_event")
    for bs in (64, 256, 1024):
        n, dt, batches = run(bs)
        print(f"{bs},{n},{dt:.2f},{n/dt:,.0f},{dt/max(n,1)*1e6:.1f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
