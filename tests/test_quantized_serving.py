"""Million-item serving: D-tiled stage A + int8 quantized corpus (§8.4).

Four layers of pins:

  * quantization contract — per-row power-of-two scales, round-trip
    error bound (property sweep over adversarial magnitude
    distributions), partition invariance;
  * BITWISE kernel/oracle parity — the int8 D-tiled kernel
    (interpret-mode Pallas) against `ref.dtiled_topk_ref` (the cpu
    dispatch) on prime Q/M/D shapes, the ISSUE-7 acceptance contract:
    exact int32 MXU partials + power-of-two scale application make the
    scores invariant to gemm blocking and FMA contraction;
  * ranking quality — top-n overlap between int8 and fp32 serving on a
    well-separated corpus (identical) and an adversarial near-tie
    corpus (bounded divergence);
  * the cache/engine layer — `StateStore.quantized_corpus` row
    invalidation re-quantizes only touched rows, and both engines'
    ``quantized=True`` request paths match the direct pipeline.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import knn
from repro.kernels import ops, ref
from repro.kernels.knn_topk import knn_topk, knn_topk_dtiled, tiled_sqnorm
from repro.kernels.serving_topn import blend_topn_rows_quant
from repro.optim.compression import (dequantize_int8_rows,
                                     quantize_int8_rows)


def _quant(rng, m, d, scale=1.0):
    corpus = (rng.normal(size=(m, d)) * scale).astype(np.float32)
    cq, cs = quantize_int8_rows(jnp.asarray(corpus))
    return jnp.asarray(corpus), cq, cs


# ---------------------------------------------------------------------------
# quantize_int8_rows: round-trip property sweep
# ---------------------------------------------------------------------------

def test_rows_roundtrip_error_bound_property(rng):
    """Property sweep (seeded draws over adversarial magnitude
    distributions): per-element round-trip error ≤ scale/2, scale is a
    power of two within 2× of the tight max|row|/127, q ∈ [−127, 127]."""
    cases = [
        rng.normal(size=(17, 23)),
        rng.normal(size=(5, 301)) * 1e-6,           # tiny rows
        rng.normal(size=(5, 301)) * 1e6,            # huge rows
        rng.normal(size=(9, 31)) * np.exp(
            rng.uniform(-20, 20, size=(9, 1))),     # mixed magnitudes
        np.zeros((3, 8)),                           # degenerate zero rows
        np.eye(7, 13),                              # single-spike rows
    ]
    for x in cases:
        x = jnp.asarray(x.astype(np.float32))
        q, s = quantize_int8_rows(x)
        qn, sn = np.asarray(q), np.asarray(s)
        assert qn.dtype == np.int8 and np.all(np.abs(qn) <= 127)
        # power-of-two scales: log2 is integral (the exactness invariant)
        assert np.all(np.log2(sn) % 1.0 == 0.0)
        amax = np.max(np.abs(np.asarray(x)), axis=1)
        tight = np.maximum(amax, 1e-30) / 127.0
        assert np.all(sn >= tight - 1e-38)          # scale admits max|row|
        assert np.all(sn <= 2.0 * tight + 1e-38)    # ≤ 1 bit of headroom
        err = np.abs(np.asarray(dequantize_int8_rows(q, s))
                     - np.asarray(x))
        assert np.all(err <= sn[:, None] / 2 * (1 + 1e-6))


def test_rows_quantization_partition_invariant(rng):
    """A row's (q, scale) must not depend on which slice holds it —
    the property the sharded int8 merge relies on (§8.4)."""
    x = jnp.asarray(rng.normal(size=(12, 19)).astype(np.float32))
    q, s = quantize_int8_rows(x)
    for sl in (slice(0, 5), slice(5, 12), slice(3, 4)):
        qs_, ss_ = quantize_int8_rows(x[sl])
        np.testing.assert_array_equal(np.asarray(qs_), np.asarray(q[sl]))
        np.testing.assert_array_equal(np.asarray(ss_), np.asarray(s[sl]))


def test_tiled_sqnorm_kernel_ref_duplicates_agree(rng):
    """ref.tiled_sqnorm_ref is a deliberate duplicate of
    knn_topk.tiled_sqnorm (the oracle imports no kernel modules); this
    pin is what licenses the duplication."""
    xf = jnp.asarray(rng.normal(size=(11, 53)).astype(np.float32))
    xq, _ = quantize_int8_rows(xf)
    for bd in (8, 16, 53, 64):
        np.testing.assert_array_equal(
            np.asarray(tiled_sqnorm(xf, bd)),
            np.asarray(ref.tiled_sqnorm_ref(xf, bd)))
        np.testing.assert_array_equal(
            np.asarray(tiled_sqnorm(xq, bd)),
            np.asarray(ref.tiled_sqnorm_ref(xq, bd)))


# ---------------------------------------------------------------------------
# D-tiled kernel vs oracle (prime Q/M/D — masked tails on every axis)
# ---------------------------------------------------------------------------

def test_dtiled_fp32_matches_ref_prime_dims(rng):
    q = jnp.asarray(rng.normal(size=(13, 71)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(103, 71)).astype(np.float32))
    for bq, bm, bd in ((4, 16, 16), (13, 103, 32), (5, 7, 71)):
        v, i = knn_topk_dtiled(q, c, k=9, bq=bq, bm=bm, bd=bd,
                               interpret=True)
        rv, ri = ref.dtiled_topk_ref(q, c, k=9, bd=bd)
        np.testing.assert_allclose(np.asarray(v), np.asarray(rv),
                                   atol=1e-4, rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


def test_dtiled_fp32_matches_monolithic_neighbours(rng):
    q = jnp.asarray(rng.normal(size=(7, 29)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(61, 29)).astype(np.float32))
    gids = jnp.arange(7, dtype=jnp.int32) * 8
    _, i_d = knn_topk_dtiled(q, c, k=5, bd=8, interpret=True,
                             query_gids=gids)
    _, i_m = knn_topk(q, c, k=5, interpret=True, query_gids=gids)
    np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_m))


def test_dtiled_int8_bitwise_prime_dims(rng):
    """THE acceptance pin: int8 D-tiled Pallas (interpret) is
    bit-for-bit the XLA oracle on prime Q/M/D, across block shapes."""
    corpus, cq, cs = _quant(rng, 101, 67)
    uids = jnp.asarray(rng.choice(101, 13, replace=False).astype(np.int32))
    qq, qs = cq[uids], cs[uids]
    for bq, bm, bd in ((3, 13, 16), (13, 101, 67), (4, 32, 32)):
        v, i = knn_topk_dtiled(qq, cq, k=7, bq=bq, bm=bm, bd=bd,
                               interpret=True, query_gids=uids,
                               q_scale=qs, c_scale=cs)
        rv, ri = ref.dtiled_topk_ref(qq, cq, k=7, bd=bd, query_gids=uids,
                                     q_scale=qs, c_scale=cs)
        np.testing.assert_array_equal(np.asarray(v), np.asarray(rv),
                                      err_msg=f"{(bq, bm, bd)}")
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


def test_dtiled_int8_bitwise_shard_offsets(rng):
    """Sharded candidate mode (global-id exclusion + sub_qnorm) stays
    bitwise too — the cross-shard merge consumes these raw scores."""
    _, cq, cs = _quant(rng, 53, 41)
    uids = jnp.asarray((rng.choice(53, 6, replace=False) * 3 + 1)
                       .astype(np.int32))
    qq, qs = cq[uids // 3], cs[uids // 3]
    v, i = knn_topk_dtiled(qq, cq, k=5, bq=4, bm=16, bd=16,
                           interpret=True, query_gids=uids, col_offset=1,
                           col_stride=3, sub_qnorm=True, q_scale=qs,
                           c_scale=cs)
    rv, ri = ref.dtiled_topk_ref(qq, cq, k=5, bd=16, query_gids=uids,
                                 col_offset=1, col_stride=3,
                                 sub_qnorm=True, q_scale=qs, c_scale=cs)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


def test_dtiled_tie_break_duplicate_rows(rng):
    """Duplicate corpus rows ⇒ exact-score ties; the running merge must
    keep lax.top_k's lowest-index winner through the D-tile axis."""
    c0 = rng.normal(size=(20, 24)).astype(np.float32)
    c = jnp.asarray(np.concatenate([c0, c0, c0], axis=0))
    q = jnp.asarray(rng.normal(size=(7, 24)).astype(np.float32))
    v, i = knn_topk_dtiled(q, c, k=11, bq=4, bm=16, bd=8, interpret=True)
    rv, ri = ref.dtiled_topk_ref(q, c, k=11, bd=8)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv), atol=1e-5)


def test_dtiled_empty_and_guards():
    out = knn_topk_dtiled(jnp.zeros((4, 8)), jnp.zeros((0, 8)), k=3,
                          interpret=True)
    assert out[0].shape == (4, 3) and np.all(np.asarray(out[0]) == -np.inf)
    out = knn_topk_dtiled(jnp.zeros((0, 8)), jnp.zeros((5, 8)), k=3,
                          interpret=True)
    assert out[0].shape == (0, 3)
    with pytest.raises(ValueError, match="q_scale"):
        knn_topk_dtiled(jnp.zeros((2, 8), jnp.int8),
                        jnp.zeros((5, 8), jnp.int8), k=2, interpret=True)
    with pytest.raises(ValueError, match="bd"):
        knn_topk_dtiled(jnp.zeros((2, 2048), jnp.int8),
                        jnp.zeros((5, 2048), jnp.int8), k=2, bd=2048,
                        interpret=True, q_scale=jnp.ones(2),
                        c_scale=jnp.ones(5))


# ---------------------------------------------------------------------------
# dispatch parity: quant pipeline cpu(ref) vs interpret Pallas
# ---------------------------------------------------------------------------

def test_blend_rows_quant_kernel_matches_ref(rng):
    corpus, cq, cs = _quant(rng, 31, 43)
    uids = rng.choice(31, 5, replace=False).astype(np.int32)
    nbr = rng.integers(0, 31, size=(5, 4)).astype(np.int32)
    qq, qs = cq[uids], cs[uids]
    nq, ns = cq[nbr], cs[nbr]
    _, ids = blend_topn_rows_quant(qq, qs, nq, ns, alpha=0.6, topn=7,
                                   bq=2, bi=16, interpret=True)
    want = ref.blend_topn_rows_quant_ref(qq, qs, nq, ns, 0.6, 7)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(want))


def test_fused_recommend_quant_interpret_matches_cpu(rng):
    corpus, cq, cs = _quant(rng, 101, 67)
    uids = jnp.asarray(rng.choice(101, 9, replace=False).astype(np.int32))
    with ops.default_impl("ref"):
        want = ops.fused_recommend_quant(cq, cs, uids, k=7, alpha=0.7,
                                         topn=6, bd=16)
    with ops.default_impl("interpret"):
        got = ops.fused_recommend_quant(cq, cs, uids, k=7, alpha=0.7,
                                        topn=6, bd=16)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_shard_topk_quant_interpret_matches_cpu(rng):
    _, cq, cs = _quant(rng, 53, 29)
    uids = jnp.asarray((rng.choice(53, 6, replace=False) * 2)
                       .astype(np.int32))
    qq, qs = cq[uids // 2], cs[uids // 2]
    with ops.default_impl("ref"):
        wv, wg = ops.shard_topk_quant(qq, qs, cq, cs, 5, shard=0,
                                      n_shards=2, query_gids=uids, bd=8)
    with ops.default_impl("interpret"):
        gv, gg = ops.shard_topk_quant(qq, qs, cq, cs, 5, shard=0,
                                      n_shards=2, query_gids=uids, bd=8)
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))
    np.testing.assert_array_equal(np.asarray(gg), np.asarray(wg))


def test_sharded_quant_matches_single_corpus(rng):
    """Row-wise quantization is partition invariant, so the sharded
    int8 pipeline must be bitwise the single-corpus one."""
    from repro.parallel.sharding import UserShardSpec
    m, n_items = 23, 37
    corpus = jnp.asarray(rng.normal(size=(m, n_items)).astype(np.float32))
    cq, cs = quantize_int8_rows(corpus)
    users = rng.choice(m, 9, replace=False)
    want = np.asarray(knn.recommend_for_users_quant(
        cq, cs, jnp.asarray(users.astype(np.int32)), k=7, alpha=0.7,
        topn=6, bd=8))
    for n_shards in (2, 3):
        spec = UserShardSpec(m, n_shards)
        quant_corpora = [quantize_int8_rows(corpus[spec.owned_users(s)])
                         for s in range(n_shards)]
        got = knn.sharded_recommend_for_users_quant(
            quant_corpora, users, k=7, alpha=0.7, topn=6,
            n_shards=n_shards, bd=8)
        np.testing.assert_array_equal(got, want, err_msg=f"S={n_shards}")


# ---------------------------------------------------------------------------
# ranking quality: int8 vs fp32
# ---------------------------------------------------------------------------

def _topn_overlap(a: np.ndarray, b: np.ndarray) -> float:
    return float(np.mean([len(set(x) & set(y)) / len(x)
                          for x, y in zip(a, b)]))


def test_int8_vs_fp32_topn_separated_corpus(rng):
    """Well-separated scores: quantization noise (≤ max|row|/127 per
    element) cannot flip any ranking — top-n must be identical."""
    corpus, cq, cs = _quant(rng, 64, 48)
    uids = jnp.asarray(rng.choice(64, 8, replace=False).astype(np.int32))
    fp = np.asarray(knn.recommend_for_users(corpus, uids, k=5, alpha=0.7,
                                            topn=5))
    q8 = np.asarray(knn.recommend_for_users_quant(cq, cs, uids, k=5,
                                                  alpha=0.7, topn=5))
    assert _topn_overlap(fp, q8) == 1.0


def test_int8_vs_fp32_topn_adversarial_near_ties(rng):
    """Adversarial near-tie corpus: rows are perturbations of a few base
    vectors at amplitude BELOW the int8 quantization step, so int8
    cannot order within a cluster.  TOLERATED DIVERGENCE: the top-n may
    permute within near-tied clusters (set overlap < 1), but the
    recommended sets still come from the same clusters — mean top-n
    overlap must stay ≥ 0.6.  This documents the quality floor a
    deployment accepts for the 4× HBM saving; anything below it means
    quantization is distorting more than tie order."""
    base = rng.normal(size=(4, 40)).astype(np.float32)
    rows = base[rng.integers(0, 4, size=64)]
    corpus = jnp.asarray(rows + 1e-4 * rng.normal(size=rows.shape)
                         .astype(np.float32))
    cq, cs = quantize_int8_rows(corpus)
    uids = jnp.asarray(rng.choice(64, 8, replace=False).astype(np.int32))
    fp = np.asarray(knn.recommend_for_users(corpus, uids, k=5, alpha=0.7,
                                            topn=5))
    q8 = np.asarray(knn.recommend_for_users_quant(cq, cs, uids, k=5,
                                                  alpha=0.7, topn=5))
    assert _topn_overlap(fp, q8) >= 0.6


# ---------------------------------------------------------------------------
# StateStore quantized corpus cache
# ---------------------------------------------------------------------------

def _store_with_state(rng, n_users=16, n_items=41):
    from repro.core import TifuParams
    from repro.streaming import StateStore, StoreConfig, StreamingEngine
    p = TifuParams(n_items=n_items, group_size=3, k_neighbors=4, alpha=0.7)
    store = StateStore(StoreConfig(n_users=n_users, n_items=n_items,
                                   max_baskets=8, max_basket_size=6))
    eng = StreamingEngine(store, p, batch_size=16)
    for u in range(n_users):
        eng.add_basket(u, rng.choice(n_items, size=3, replace=False))
    eng.run_until_drained()
    return eng, store


def test_quantized_corpus_cache_row_invalidation(rng):
    eng, store = _store_with_state(rng)
    q0, s0 = store.quantized_corpus()
    assert store.quant_full_builds == 1
    wq, ws = quantize_int8_rows(store.corpus())
    np.testing.assert_array_equal(np.asarray(q0), np.asarray(wq))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(ws))
    # touch two users: the refresh must re-quantize ONLY those rows and
    # still agree bitwise with a from-scratch quantization
    eng.add_basket(3, rng.choice(41, size=3, replace=False))
    eng.add_basket(7, rng.choice(41, size=3, replace=False))
    eng.run_until_drained()
    q1, s1 = store.quantized_corpus()
    assert store.quant_full_builds == 1          # no rebuild
    assert store.quant_rows_refreshed == 2
    wq, ws = quantize_int8_rows(store.corpus())
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(wq))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(ws))


def test_quantized_corpus_threshold_rebuild(rng):
    eng, store = _store_with_state(rng)
    store.quantized_corpus()
    # dirty more than corpus_rebuild_frac of users → one full rebuild
    for u in range(8):
        eng.add_basket(u, rng.choice(41, size=3, replace=False))
    eng.run_until_drained()
    store.quantized_corpus()
    assert store.quant_threshold_rebuilds == 1
    assert store.quant_rows_refreshed == 0


def test_quantized_corpus_degraded_serving(rng):
    eng, store = _store_with_state(rng)
    q0, s0 = store.quantized_corpus()
    frozen_q = np.asarray(q0).copy()
    store.freeze_serving()
    eng.add_basket(0, rng.choice(41, size=3, replace=False))
    eng.run_until_drained()
    qf, _ = store.quantized_corpus()             # pinned snapshot
    np.testing.assert_array_equal(np.asarray(qf), frozen_q)
    store.thaw_serving()
    q1, s1 = store.quantized_corpus()            # live again
    wq, _ = quantize_int8_rows(store.corpus())
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(wq))


# ---------------------------------------------------------------------------
# engine request batchers
# ---------------------------------------------------------------------------

def test_engine_recommend_quantized(rng):
    eng, store = _store_with_state(rng)
    users = rng.choice(16, size=5, replace=False)
    got = eng.recommend(users, topn=5, quantized=True)
    assert got.shape == (5, 5)
    cq, cs = store.quantized_corpus()
    want = np.asarray(knn.recommend_for_users_quant(
        cq, cs, jnp.asarray(users.astype(np.int32)), k=4, alpha=0.7,
        topn=5))
    np.testing.assert_array_equal(got, want)
    with pytest.raises(ValueError, match="euclidean"):
        eng.recommend(users, topn=5, metric="cosine", quantized=True)
    # quantized requests bucket separately in the compiled-shape count
    before = eng.metrics.serve_compiled_shapes
    eng.recommend(users, topn=5, quantized=False)
    assert eng.metrics.serve_compiled_shapes == before + 1


def test_sharded_engine_recommend_quantized(rng):
    from repro.core import TifuParams
    from repro.parallel.sharding import UserShardSpec
    from repro.streaming import ShardedStreamingEngine
    p = TifuParams(n_items=29, group_size=3, k_neighbors=4, alpha=0.7)
    spec = UserShardSpec(12, 2)
    eng = ShardedStreamingEngine.create(spec, p, max_baskets=8,
                                        max_basket_size=6, batch_size=8)
    for u in range(12):
        eng.add_basket(u, rng.choice(29, size=3, replace=False))
    eng.run_until_drained()
    users = rng.choice(12, size=5, replace=False)
    got = eng.recommend(users, topn=5, quantized=True)
    want = knn.sharded_recommend_for_users_quant(
        eng.quantized_corpora(), users, k=4, alpha=0.7, topn=5,
        n_shards=2)
    np.testing.assert_array_equal(got, np.asarray(want)[:len(users)])
